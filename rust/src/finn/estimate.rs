//! Whole-network LUT roll-up under the four accumulator co-design policies
//! of paper §5.3 (Fig. 6): fixed 32-bit, per-layer data-type bound, per-layer
//! post-training weight-norm minimization (PTM), and A2Q's user-specified P.

use super::mvau::{self, LutBreakdown};
use super::thresholds;
use crate::quant::bounds::{self, DotShape};

/// Geometry of one layer, mirrored from the artifact manifest (the Rust side
/// trusts `python/compile/models/*.py` QLayer metadata, which is itself
/// cross-checked against the parameter tensors in pytest).
#[derive(Clone, Debug)]
pub struct LayerGeom {
    pub name: String,
    /// 'dense' | 'conv' | 'dwconv'
    pub kind: String,
    pub c_out: usize,
    pub k: usize,
    /// Bit-width specs: fixed width, or the runtime variable ("M"/"N"/"P").
    pub m_spec: BitSpec,
    pub n_spec: BitSpec,
    pub p_spec: BitSpec,
    pub x_signed: bool,
    pub out_h: usize,
    pub out_w: usize,
    pub kh: usize,
    pub c_in: usize,
    pub stride: usize,
}

/// Fixed bit width or one of the runtime grid variables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BitSpec {
    Fixed(u32),
    M,
    N,
    P,
}

impl BitSpec {
    pub fn resolve(self, m: u32, n: u32, p: u32) -> u32 {
        match self {
            BitSpec::Fixed(v) => v,
            BitSpec::M => m,
            BitSpec::N => n,
            BitSpec::P => p,
        }
    }

    /// True for layers whose accumulator is the A2Q-constrained runtime P.
    pub fn is_runtime_p(self) -> bool {
        self == BitSpec::P
    }
}

/// Resolved per-layer bit widths.
#[derive(Clone, Copy, Debug)]
pub struct LayerBits {
    pub m: u32,
    pub n_in: u32,
    pub n_out: u32,
    pub p: u32,
}

/// Accumulator selection policy (the four Fig. 6 settings).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AccumulatorPolicy {
    /// Baseline: every accumulator is 32 bits.
    Fixed32,
    /// Per-layer minimum from the data-type bound (Eq. 8).
    DataTypeBound,
    /// Post-training minimization: per-layer weight-norm bound (Eq. 12) on
    /// the trained weights' l1 norms (supplied per layer).
    WeightNorm,
    /// A2Q: hidden layers use the user target P (overflow is guaranteed
    /// impossible by training); fixed 8-bit boundary layers fall back to
    /// their weight-norm bound (they were trained with loose caps).
    A2qTarget(u32),
}

/// One layer's estimate.
#[derive(Clone, Debug)]
pub struct LayerEstimate {
    pub name: String,
    pub luts: LutBreakdown,
    pub p_used: u32,
    pub pe: usize,
    pub simd: usize,
}

/// Whole-network estimate.
#[derive(Clone, Debug)]
pub struct NetworkEstimate {
    pub layers: Vec<LayerEstimate>,
    pub total: LutBreakdown,
}

impl NetworkEstimate {
    pub fn total_luts(&self) -> f64 {
        self.total.total()
    }
}

/// Default cycles-per-frame folding budget (matches a mid-size FINN build).
pub const DEFAULT_CYCLES_BUDGET: usize = 4096;

/// Select the accumulator width for one layer under a policy.
///
/// `l1_norm` is the layer's max per-channel integer-weight l1 norm (used by
/// WeightNorm / the A2Q boundary-layer fallback). Every policy is floored at
/// the width needed for correctness, and capped at 32 like the paper's
/// baseline register.
pub fn select_p(
    geom: &LayerGeom,
    bits: (u32, u32, u32),
    policy: AccumulatorPolicy,
    l1_norm: Option<f64>,
) -> u32 {
    let (m, n, p) = bits;
    let n_in = geom.n_spec.resolve(m, n, p);
    let shape = DotShape {
        k: geom.k,
        m_bits: geom.m_spec.resolve(m, n, p),
        n_bits: n_in,
        x_signed: geom.x_signed,
    };
    let dt = bounds::data_type_bound(shape).min(32);
    let wn = l1_norm
        .map(|l1| bounds::weight_bound(l1, n_in, geom.x_signed).min(32))
        .unwrap_or(dt);
    match policy {
        AccumulatorPolicy::Fixed32 => 32,
        AccumulatorPolicy::DataTypeBound => dt,
        AccumulatorPolicy::WeightNorm => wn.min(dt),
        AccumulatorPolicy::A2qTarget(target) => {
            if geom.p_spec.is_runtime_p() {
                target.min(dt)
            } else {
                wn.min(dt)
            }
        }
    }
}

/// Estimate one layer at resolved bit widths.
pub fn estimate_layer(geom: &LayerGeom, lb: LayerBits, cycles_budget: usize) -> LayerEstimate {
    let out_pixels = geom.out_h * geom.out_w;
    let cfg = mvau::fold(geom.c_out, geom.k, out_pixels, cycles_budget);

    let mut luts = LutBreakdown::default();
    luts.compute += mvau::compute_luts(cfg, lb.m, lb.n_in, lb.p);
    luts.compute += thresholds::threshold_compare_luts(cfg.pe, lb.p);
    luts.memory += mvau::weight_memory_luts(geom.c_out, geom.k, lb.m);
    luts.memory += thresholds::threshold_memory_luts(geom.c_out, lb.n_out, lb.p);
    if geom.kind != "dense" {
        let in_w = geom.out_w * geom.stride;
        luts.memory += thresholds::window_buffer_luts(geom.kh, in_w, geom.c_in, lb.n_in);
    }

    LayerEstimate { name: geom.name.clone(), luts, p_used: lb.p, pe: cfg.pe, simd: cfg.simd }
}

/// Estimate the whole network at grid point `(m, n, p)` under a policy.
///
/// `l1_norms[i]` is layer i's max per-channel integer l1 norm from the
/// export artifact (None -> data-type fallback, used for Fixed32/DataType).
pub fn estimate_network(
    geoms: &[LayerGeom],
    bits: (u32, u32, u32),
    policy: AccumulatorPolicy,
    l1_norms: Option<&[f64]>,
    cycles_budget: usize,
) -> NetworkEstimate {
    let (m, n, p) = bits;
    let mut layers = Vec::with_capacity(geoms.len());
    let mut total = LutBreakdown::default();
    for (i, g) in geoms.iter().enumerate() {
        let l1 = l1_norms.and_then(|v| v.get(i).copied());
        let p_used = select_p(g, bits, policy, l1);
        // N_out = the next layer's input precision; the last layer emits
        // 8-bit outputs (paper fixes boundary layers at 8 bits).
        let n_out = geoms
            .get(i + 1)
            .map(|nx| nx.n_spec.resolve(m, n, p))
            .unwrap_or(8);
        let lb = LayerBits {
            m: g.m_spec.resolve(m, n, p),
            n_in: g.n_spec.resolve(m, n, p),
            n_out,
            p: p_used,
        };
        let est = estimate_layer(g, lb, cycles_budget);
        total.add(est.luts);
        layers.push(est);
    }
    NetworkEstimate { layers, total }
}

/// Estimate a simulated [`crate::model::QNetwork`] directly: geometry, per
/// layer bit widths and max per-channel integer l1 norms all come from the
/// network itself ([`crate::model::QNetwork::geoms`]) instead of hand-built
/// [`LayerGeom`] lists, so `a2q netsim` and the network figures price
/// exactly the network they simulated.
pub fn estimate_qnetwork(
    net: &crate::model::QNetwork,
    policy: AccumulatorPolicy,
    cycles_budget: usize,
) -> NetworkEstimate {
    let l1 = net.layer_l1_norms();
    estimate_network(&net.geoms(), net.grid_bits(), policy, Some(&l1), cycles_budget)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_net() -> Vec<LayerGeom> {
        vec![
            LayerGeom {
                name: "stem".into(),
                kind: "conv".into(),
                c_out: 32,
                k: 27,
                m_spec: BitSpec::Fixed(8),
                n_spec: BitSpec::Fixed(8),
                p_spec: BitSpec::Fixed(32),
                x_signed: false,
                out_h: 16,
                out_w: 16,
                kh: 3,
                c_in: 3,
                stride: 1,
            },
            LayerGeom {
                name: "mid".into(),
                kind: "conv".into(),
                c_out: 64,
                k: 288,
                m_spec: BitSpec::M,
                n_spec: BitSpec::N,
                p_spec: BitSpec::P,
                x_signed: false,
                out_h: 8,
                out_w: 8,
                kh: 3,
                c_in: 32,
                stride: 2,
            },
            LayerGeom {
                name: "head".into(),
                kind: "dense".into(),
                c_out: 10,
                k: 64,
                m_spec: BitSpec::Fixed(8),
                n_spec: BitSpec::Fixed(8),
                p_spec: BitSpec::Fixed(32),
                x_signed: false,
                out_h: 1,
                out_w: 1,
                kh: 1,
                c_in: 64,
                stride: 1,
            },
        ]
    }

    #[test]
    fn policies_are_ordered() {
        // Fixed32 >= DataType >= WeightNorm >= A2Q(low target) in total LUTs.
        let net = toy_net();
        let bits = (6, 6, 16);
        let l1 = vec![300.0, 900.0, 90.0];
        let f32_ = estimate_network(&net, bits, AccumulatorPolicy::Fixed32, Some(&l1), 4096);
        let dt = estimate_network(&net, bits, AccumulatorPolicy::DataTypeBound, Some(&l1), 4096);
        let wn = estimate_network(&net, bits, AccumulatorPolicy::WeightNorm, Some(&l1), 4096);
        let a2q = estimate_network(&net, bits, AccumulatorPolicy::A2qTarget(12), Some(&l1), 4096);
        assert!(f32_.total_luts() > dt.total_luts());
        assert!(dt.total_luts() >= wn.total_luts());
        assert!(wn.total_luts() >= a2q.total_luts());
    }

    #[test]
    fn a2q_target_only_touches_runtime_p_layers() {
        let net = toy_net();
        let l1 = vec![300.0, 900.0, 90.0];
        let est =
            estimate_network(&net, (6, 6, 10), AccumulatorPolicy::A2qTarget(10), Some(&l1), 4096);
        assert_eq!(est.layers[1].p_used, 10); // hidden layer takes the target
        assert_ne!(est.layers[0].p_used, 10); // boundary layers use their bound
    }

    #[test]
    fn select_p_never_exceeds_data_type_bound() {
        let net = toy_net();
        for p in [8u32, 12, 16, 24, 32] {
            let sel = select_p(&net[1], (8, 8, p), AccumulatorPolicy::A2qTarget(p), Some(1e9));
            let dt = bounds::data_type_bound(DotShape {
                k: 288,
                m_bits: 8,
                n_bits: 8,
                x_signed: false,
            });
            assert!(sel <= dt.min(32));
        }
    }

    #[test]
    fn qnetwork_estimates_keep_policy_ordering() {
        use crate::model::{NetSpec, QNetwork, SynthQuant};
        // Unconstrained (QAT-like) weights: their l1 norms are large, so
        // the policy ordering Fixed32 > DataType >= WeightNorm >= A2Q holds.
        let spec = NetSpec {
            widths: vec![64, 32, 10],
            m_bits: 5,
            n_bits: 4,
            p_bits: 12,
            x_signed: false,
            quant: SynthQuant::Affine,
        };
        let net = QNetwork::synthesize(&spec, 13).unwrap();
        let f32_ = estimate_qnetwork(&net, AccumulatorPolicy::Fixed32, 4096);
        let dt = estimate_qnetwork(&net, AccumulatorPolicy::DataTypeBound, 4096);
        let wn = estimate_qnetwork(&net, AccumulatorPolicy::WeightNorm, 4096);
        let a2q = estimate_qnetwork(&net, AccumulatorPolicy::A2qTarget(12), 4096);
        assert_eq!(f32_.layers.len(), 2);
        assert!(f32_.total_luts() > dt.total_luts());
        assert!(dt.total_luts() >= wn.total_luts());
        assert!(wn.total_luts() >= a2q.total_luts());
        // every synthesized layer carries runtime P, so the target applies
        assert!(a2q.layers.iter().all(|l| l.p_used <= 12));

        // An A2Q-*constrained* net's trained weight norms certify its target
        // (or tighter): the weight-norm estimate never exceeds the target's.
        let trained = QNetwork::synthesize(&NetSpec { quant: SynthQuant::A2q, ..spec }, 13).unwrap();
        let wn_t = estimate_qnetwork(&trained, AccumulatorPolicy::WeightNorm, 4096);
        let a2q_t = estimate_qnetwork(&trained, AccumulatorPolicy::A2qTarget(12), 4096);
        assert!(wn_t.total_luts() <= a2q_t.total_luts());
    }

    #[test]
    fn narrower_bits_mean_fewer_luts() {
        let net = toy_net();
        let hi = estimate_network(&net, (8, 8, 32), AccumulatorPolicy::A2qTarget(32), None, 4096);
        let lo = estimate_network(&net, (5, 5, 12), AccumulatorPolicy::A2qTarget(12), None, 4096);
        assert!(lo.total_luts() < hi.total_luts());
        // both compute and memory move (Fig. 7's two bars)
        assert!(lo.total.compute < hi.total.compute);
        assert!(lo.total.memory < hi.total.memory);
    }
}
