//! BSD300 substitute for 3x single-image super-resolution: band-limited
//! grayscale textures.
//!
//! Each sample is a 48x48 high-resolution patch built from a random mixture
//! of oriented sinusoids (low through mid spatial frequencies) plus a soft
//! edge, snapped to the 8-bit grid; the network input is its 3x3 box-
//! downsampled 16x16 version. Super-resolving band-limited texture is
//! exactly the regime where PSNR degrades smoothly with quantization, which
//! is what the ESPCN/UNet rows of Figs. 4-6 measure.

use super::{loader::Dataset, snap_to_grid};
use crate::rng::Rng;

pub const LR_SIDE: usize = 16;
pub const FACTOR: usize = 3;
pub const HR_SIDE: usize = LR_SIDE * FACTOR;
pub const LR_DIM: usize = LR_SIDE * LR_SIDE;
pub const HR_DIM: usize = HR_SIDE * HR_SIDE;

fn draw_hr(rng: &mut Rng, hr: &mut [f64]) {
    let n_waves = 3 + rng.below(4);
    let waves: Vec<(f64, f64, f64, f64)> = (0..n_waves)
        .map(|_| {
            let theta = rng.uniform() * std::f64::consts::PI;
            // wavelength 6..24 px: representable at LR after 3x downsampling
            let freq = 2.0 * std::f64::consts::PI / (6.0 + rng.uniform() * 18.0);
            let phase = rng.uniform() * 2.0 * std::f64::consts::PI;
            let amp = 0.1 + rng.uniform() * 0.25;
            (theta, freq, phase, amp)
        })
        .collect();
    // one soft edge per patch
    let edge_theta = rng.uniform() * std::f64::consts::PI;
    let edge_off = (rng.uniform() - 0.5) * HR_SIDE as f64;
    let edge_amp = rng.uniform() * 0.3;
    for r in 0..HR_SIDE {
        for c in 0..HR_SIDE {
            let (x, y) = (c as f64 - HR_SIDE as f64 / 2.0, r as f64 - HR_SIDE as f64 / 2.0);
            let mut v = 0.5;
            for (theta, freq, phase, amp) in &waves {
                let u = x * theta.cos() + y * theta.sin();
                v += amp * (freq * u + phase).sin();
            }
            let d = x * edge_theta.cos() + y * edge_theta.sin() - edge_off;
            v += edge_amp * (d / 2.0).tanh() * 0.5;
            hr[r * HR_SIDE + c] = v;
        }
    }
}

fn box_downsample(hr: &[f32], lr: &mut [f32]) {
    for r in 0..LR_SIDE {
        for c in 0..LR_SIDE {
            let mut acc = 0.0f64;
            for dr in 0..FACTOR {
                for dc in 0..FACTOR {
                    acc += hr[(r * FACTOR + dr) * HR_SIDE + c * FACTOR + dc] as f64;
                }
            }
            lr[r * LR_SIDE + c] = snap_to_grid(acc / (FACTOR * FACTOR) as f64, 8);
        }
    }
}

/// Generate the dataset: x = 16x16x1 low-res inputs, y = 48x48x1 targets.
pub fn generate(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xb5d3_0003);
    let mut hr_f64 = vec![0.0f64; HR_DIM];
    let mut make = |n: usize, rng: &mut Rng| {
        let mut xs = vec![0.0f32; n * LR_DIM];
        let mut ys = vec![0.0f32; n * HR_DIM];
        for i in 0..n {
            draw_hr(rng, &mut hr_f64);
            let hr_img = &mut ys[i * HR_DIM..(i + 1) * HR_DIM];
            for (o, v) in hr_img.iter_mut().zip(&hr_f64) {
                *o = snap_to_grid(*v, 8);
            }
            box_downsample(hr_img, &mut xs[i * LR_DIM..(i + 1) * LR_DIM]);
        }
        (xs, ys)
    };
    let (tx, ty) = make(n_train, &mut rng);
    let (ex, ey) = make(n_test, &mut rng);
    Dataset::new(
        "synth_bsd",
        vec![LR_SIDE, LR_SIDE, 1],
        vec![HR_SIDE, HR_SIDE, 1],
        tx,
        ty,
        ex,
        ey,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Split;

    #[test]
    fn shapes_and_grid() {
        let d = generate(8, 4, 0);
        assert_eq!(d.x_shape, vec![16, 16, 1]);
        assert_eq!(d.y_shape, vec![48, 48, 1]);
        let b = d.gather(Split::Train, &[0, 1]);
        assert_eq!(b.x.shape(), &[2, 16, 16, 1]);
        assert_eq!(b.y.shape(), &[2, 48, 48, 1]);
        for v in b.y.data() {
            assert!((0.0..=1.0).contains(v));
        }
    }

    #[test]
    fn downsample_consistency() {
        // The LR input must equal the 3x3 box mean of the HR target (up to
        // 8-bit snapping of both): nearest-neighbor 3x upsampling of the LR
        // then re-downsampling must be a fixed point.
        let d = generate(4, 0, 5);
        let b = d.gather(Split::Train, &[0]);
        for r in 0..LR_SIDE {
            for c in 0..LR_SIDE {
                let mut acc = 0.0f64;
                for dr in 0..FACTOR {
                    for dc in 0..FACTOR {
                        acc += b.y.data()[(r * FACTOR + dr) * HR_SIDE + c * FACTOR + dc] as f64;
                    }
                }
                let want = snap_to_grid(acc / 9.0, 8);
                let got = b.x.data()[r * LR_SIDE + c];
                assert!((want - got).abs() < 2.5 / 255.0, "LR({r},{c}) {got} vs {want}");
            }
        }
    }

    #[test]
    fn texture_has_structure_not_noise() {
        // Neighboring pixels must be correlated (band-limited textures),
        // otherwise SR is information-theoretically hopeless.
        let d = generate(4, 0, 7);
        let b = d.gather(Split::Train, &[0]);
        let y = b.y.data();
        let mean = y.iter().map(|v| *v as f64).sum::<f64>() / HR_DIM as f64;
        let mut cov = 0.0;
        let mut var = 0.0;
        for r in 0..HR_SIDE {
            for c in 0..HR_SIDE - 1 {
                let a = y[r * HR_SIDE + c] as f64 - mean;
                let bb = y[r * HR_SIDE + c + 1] as f64 - mean;
                cov += a * bb;
                var += a * a;
            }
        }
        assert!(cov / var > 0.7, "neighbor correlation {}", cov / var);
    }
}
