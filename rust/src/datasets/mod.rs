//! Synthetic dataset substrates (DESIGN.md substitution table).
//!
//! The paper evaluates on MNIST / CIFAR10 / BSD300. A2Q's claims are about
//! arithmetic — overflow, norm constraints, resource cost — not dataset
//! semantics, so we substitute deterministic synthetic sets with identical
//! tensor shapes and dtypes, non-trivial learnable structure, and fixed
//! train/test splits:
//!
//! * [`synth_mnist`] — 28x28 **1-bit** binary stroke images, 2 classes
//!   (the Fig. 2 motivating task: K = 784, N = 1).
//! * [`synth_cifar`] — 16x16x3 images on the 8-bit grid, 10 classes built
//!   from smooth class prototypes plus noise.
//! * [`synth_bsd`]   — band-limited grayscale textures for 3x single-image
//!   super-resolution: 48x48 high-res targets, 16x16 box-downsampled inputs.
//!
//! All generation is seeded [`crate::rng::Rng`]; every experiment is
//! bit-reproducible.

pub mod loader;
pub mod synth_bsd;
pub mod synth_cifar;
pub mod synth_mnist;

pub use loader::{Batch, Dataset, Split};

/// Snap a float in [0, 1] onto the B-bit unsigned grid (emulating B-bit
/// image data, so "8-bit images" are exactly representable downstream).
pub fn snap_to_grid(v: f64, bits: u32) -> f32 {
    let levels = ((1u32 << bits) - 1) as f64;
    ((v.clamp(0.0, 1.0) * levels).round() / levels) as f32
}

/// Build the dataset named in a config: "synth_mnist" | "synth_cifar" |
/// "synth_bsd".
pub fn by_name(name: &str, n_train: usize, n_test: usize, seed: u64) -> anyhow::Result<Dataset> {
    match name {
        "synth_mnist" => Ok(synth_mnist::generate(n_train, n_test, seed)),
        "synth_cifar" => Ok(synth_cifar::generate(n_train, n_test, seed)),
        "synth_bsd" => Ok(synth_bsd::generate(n_train, n_test, seed)),
        other => Err(anyhow::anyhow!("unknown dataset {other:?}")),
    }
}

/// Default dataset for each model in the zoo (native registry MLPs all ride
/// the binary-MNIST substrate).
pub fn default_for_model(model: &str) -> &'static str {
    match model {
        m if m.starts_with("mlp") => "synth_mnist",
        "cnn" | "resnet" => "synth_cifar",
        _ => "synth_bsd",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_snapping() {
        assert_eq!(snap_to_grid(0.0, 8), 0.0);
        assert_eq!(snap_to_grid(1.0, 8), 1.0);
        let v = snap_to_grid(0.5, 8);
        assert!((v * 255.0 - (v * 255.0).round()).abs() < 1e-6);
        // 1-bit grid is {0, 1}
        assert_eq!(snap_to_grid(0.49, 1), 0.0);
        assert_eq!(snap_to_grid(0.51, 1), 1.0);
    }

    #[test]
    fn by_name_dispatch() {
        assert!(by_name("synth_mnist", 8, 4, 0).is_ok());
        assert!(by_name("nope", 8, 4, 0).is_err());
    }
}
