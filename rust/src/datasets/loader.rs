//! Dataset container + deterministic shuffled batching for the training loop.

use crate::rng::Rng;
use crate::tensor::Tensor;

/// Which half of the fixed split to read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// One training batch: inputs plus targets (class labels carried as f32 for
/// the all-f32 artifact interface, or SR target images).
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
}

/// An in-memory dataset with a fixed train/test split.
///
/// `x_shape` / `y_shape` are *per-sample* shapes; samples are stored
/// row-major and materialized into contiguous batch tensors on demand.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: &'static str,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    train_x: Vec<f32>,
    train_y: Vec<f32>,
    test_x: Vec<f32>,
    test_y: Vec<f32>,
    pub n_train: usize,
    pub n_test: usize,
}

impl Dataset {
    pub fn new(
        name: &'static str,
        x_shape: Vec<usize>,
        y_shape: Vec<usize>,
        train_x: Vec<f32>,
        train_y: Vec<f32>,
        test_x: Vec<f32>,
        test_y: Vec<f32>,
    ) -> Self {
        let xs: usize = x_shape.iter().product();
        let ys: usize = y_shape.iter().product::<usize>().max(1);
        let n_train = train_x.len() / xs;
        let n_test = test_x.len() / xs;
        assert_eq!(train_x.len(), n_train * xs);
        assert_eq!(train_y.len(), n_train * ys);
        assert_eq!(test_y.len(), n_test * ys);
        Dataset { name, x_shape, y_shape, train_x, train_y, test_x, test_y, n_train, n_test }
    }

    fn raw(&self, split: Split) -> (&[f32], &[f32], usize) {
        match split {
            Split::Train => (&self.train_x, &self.train_y, self.n_train),
            Split::Test => (&self.test_x, &self.test_y, self.n_test),
        }
    }

    pub fn len(&self, split: Split) -> usize {
        self.raw(split).2
    }

    /// Materialize the batch for the given sample indices.
    pub fn gather(&self, split: Split, idx: &[usize]) -> Batch {
        let (xs, ys, n) = self.raw(split);
        let xd: usize = self.x_shape.iter().product();
        let yd: usize = self.y_shape.iter().product::<usize>().max(1);
        let mut x = Vec::with_capacity(idx.len() * xd);
        let mut y = Vec::with_capacity(idx.len() * yd);
        for &i in idx {
            assert!(i < n, "index {i} out of range {n}");
            x.extend_from_slice(&xs[i * xd..(i + 1) * xd]);
            y.extend_from_slice(&ys[i * yd..(i + 1) * yd]);
        }
        let mut bx = vec![idx.len()];
        bx.extend(&self.x_shape);
        let mut by = vec![idx.len()];
        by.extend(&self.y_shape);
        Batch { x: Tensor::new(bx, x), y: Tensor::new(by, y) }
    }

    /// Deterministic epoch iterator: shuffled index order, fixed batch size,
    /// drops the ragged tail (HLO artifacts are shape-static).
    pub fn epoch(&self, split: Split, batch_size: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        let n = self.len(split);
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        idx.chunks(batch_size)
            .filter(|c| c.len() == batch_size)
            .map(|c| c.to_vec())
            .collect()
    }

    /// Sequential full-coverage batches for evaluation, padding the tail by
    /// wrapping (callers weight by `n_valid` to keep metrics exact).
    pub fn eval_batches(&self, split: Split, batch_size: usize) -> Vec<(Vec<usize>, usize)> {
        let n = self.len(split);
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let mut idx = Vec::with_capacity(batch_size);
            let n_valid = (n - i).min(batch_size);
            for j in 0..batch_size {
                idx.push((i + j) % n);
            }
            out.push((idx, n_valid));
            i += batch_size;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        // 5 train / 3 test samples of shape [2], scalar labels
        Dataset::new(
            "toy",
            vec![2],
            vec![],
            (0..10).map(|v| v as f32).collect(),
            (0..5).map(|v| v as f32).collect(),
            (0..6).map(|v| (100 + v) as f32).collect(),
            (0..3).map(|v| v as f32).collect(),
        )
    }

    #[test]
    fn gather_shapes() {
        let d = toy();
        let b = d.gather(Split::Train, &[0, 2]);
        assert_eq!(b.x.shape(), &[2, 2]);
        assert_eq!(b.x.data(), &[0.0, 1.0, 4.0, 5.0]);
        assert_eq!(b.y.data(), &[0.0, 2.0]);
    }

    #[test]
    fn epoch_covers_without_ragged_tail() {
        let d = toy();
        let mut rng = Rng::new(0);
        let batches = d.epoch(Split::Train, 2, &mut rng);
        assert_eq!(batches.len(), 2); // 5 samples, bs=2 -> drop tail
        let mut seen: Vec<usize> = batches.concat();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 4);
    }

    #[test]
    fn eval_batches_cover_everything() {
        let d = toy();
        let batches = d.eval_batches(Split::Test, 2);
        let covered: usize = batches.iter().map(|(_, v)| v).sum();
        assert_eq!(covered, 3);
        for (idx, _) in &batches {
            assert_eq!(idx.len(), 2); // padded to batch size
        }
    }
}
