//! Binary-MNIST substitute: 28x28 **1-bit** stroke images, 2 classes.
//!
//! Class 0 draws predominantly *vertical* strokes, class 1 predominantly
//! *horizontal* ones, with jitter, thickness variation and salt noise. The
//! classes are (approximately) linearly separable — the paper's Fig. 2 model
//! is a 1-layer linear QNN at 91.5% test accuracy, and this substrate puts a
//! linear probe in the same regime. Inputs are exactly {0, 1}: N = 1 bit,
//! K = 784, matching Appendix A.

use super::loader::Dataset;
use crate::rng::Rng;

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;

fn draw_sample(rng: &mut Rng, class: usize, img: &mut [f32]) {
    img.fill(0.0);
    let n_strokes = 2 + rng.below(3);
    for _ in 0..n_strokes {
        // Dominant orientation by class, with 20% distractor strokes.
        let vertical = if rng.uniform() < 0.8 { class == 0 } else { class == 1 };
        // Stroke lanes are class-biased (class 0 left/top third, class 1
        // right/bottom third, overlapping in the middle): this makes the two
        // classes *linearly* separable from raw pixels at the ~90% level the
        // paper's 1-layer linear QNN reaches on binary MNIST (91.5%), while
        // the orientation cue stays nonlinear.
        let lane_span = SIDE - 6 - 8;
        let pos = if class == 0 {
            3 + rng.below(lane_span)
        } else {
            3 + 8 + rng.below(lane_span)
        };
        let start = rng.below(8);
        let len = 12 + rng.below(SIDE - 12 - start);
        let thick = 1 + rng.below(2);
        for along in start..(start + len).min(SIDE) {
            // small jitter so strokes are not perfectly straight
            let wobble = (rng.uniform() * 2.0) as usize;
            for t in 0..thick {
                let lane = (pos + t + wobble).min(SIDE - 1);
                let (r, c) = if vertical { (along, lane) } else { (lane, along) };
                img[r * SIDE + c] = 1.0;
            }
        }
    }
    // salt noise: flip ~1% of pixels
    for _ in 0..8 {
        let p = rng.below(DIM);
        img[p] = 1.0 - img[p];
    }
}

/// Generate the dataset with a fixed train/test split.
pub fn generate(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5a17_0001);
    let make = |n: usize, rng: &mut Rng| {
        let mut xs = vec![0.0f32; n * DIM];
        let mut ys = vec![0.0f32; n];
        for i in 0..n {
            let class = i % 2; // balanced
            draw_sample(rng, class, &mut xs[i * DIM..(i + 1) * DIM]);
            ys[i] = class as f32;
        }
        (xs, ys)
    };
    let (tx, ty) = make(n_train, &mut rng);
    let (ex, ey) = make(n_test, &mut rng);
    Dataset::new("synth_mnist", vec![DIM], vec![], tx, ty, ex, ey)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Split;

    #[test]
    fn strictly_binary_pixels() {
        let d = generate(32, 16, 0);
        let b = d.gather(Split::Train, &(0..32).collect::<Vec<_>>());
        assert!(b.x.data().iter().all(|v| *v == 0.0 || *v == 1.0));
    }

    #[test]
    fn balanced_labels() {
        let d = generate(100, 10, 1);
        let b = d.gather(Split::Train, &(0..100).collect::<Vec<_>>());
        let ones = b.y.data().iter().filter(|v| **v == 1.0).count();
        assert_eq!(ones, 50);
    }

    #[test]
    fn deterministic() {
        let a = generate(16, 4, 9);
        let b = generate(16, 4, 9);
        let ba = a.gather(Split::Test, &[0, 1]);
        let bb = b.gather(Split::Test, &[0, 1]);
        assert_eq!(ba.x.data(), bb.x.data());
    }

    #[test]
    fn classes_linearly_separable() {
        // A *linear* probe (nearest class mean == linear decision rule) fit
        // on train must generalize to held-out test data at the level the
        // paper's 1-layer linear QNN reaches on binary MNIST (~91.5%):
        // this is exactly the signal the Fig. 2 model needs.
        let d = generate(400, 200, 3);
        let tr = d.gather(Split::Train, &(0..400).collect::<Vec<_>>());
        let te = d.gather(Split::Test, &(0..200).collect::<Vec<_>>());
        let mut means = vec![vec![0.0f64; DIM]; 2];
        let mut counts = [0usize; 2];
        for i in 0..400 {
            let cls = tr.y.data()[i] as usize;
            counts[cls] += 1;
            for j in 0..DIM {
                means[cls][j] += tr.x.data()[i * DIM + j] as f64;
            }
        }
        for (m, c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= *c as f64;
            }
        }
        let mut correct = 0;
        for i in 0..200 {
            let x = &te.x.data()[i * DIM..(i + 1) * DIM];
            let d0: f64 = x.iter().zip(&means[0]).map(|(v, m)| (*v as f64 - m).powi(2)).sum();
            let d1: f64 = x.iter().zip(&means[1]).map(|(v, m)| (*v as f64 - m).powi(2)).sum();
            let pred = if d0 < d1 { 0.0 } else { 1.0 };
            if pred == te.y.data()[i] {
                correct += 1;
            }
        }
        assert!(correct > 160, "linear probe only {correct}/200");
    }
}
