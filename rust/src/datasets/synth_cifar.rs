//! CIFAR10 substitute: 16x16x3 images on the 8-bit grid, 10 classes.
//!
//! Each class owns a smooth random prototype (a mixture of low-frequency
//! color gradients and 2-3 Gaussian blobs); samples are the prototype under
//! a random gain/shift plus pixel noise, snapped to the 8-bit grid. The
//! structure is deliberately conv-friendly (local correlations, class-
//! specific color statistics) and hard enough that accuracy degrades
//! smoothly as quantization tightens — which is what Figs. 4-6 measure.

use super::{loader::Dataset, snap_to_grid};
use crate::rng::Rng;

pub const SIDE: usize = 16;
pub const CHANNELS: usize = 3;
pub const DIM: usize = SIDE * SIDE * CHANNELS;
pub const CLASSES: usize = 10;

struct Prototype {
    base: Vec<f64>, // DIM
}

fn make_prototype(rng: &mut Rng) -> Prototype {
    let mut base = vec![0.0f64; DIM];
    // low-frequency color gradient
    let gx: Vec<f64> = (0..CHANNELS).map(|_| rng.normal() * 0.15).collect();
    let gy: Vec<f64> = (0..CHANNELS).map(|_| rng.normal() * 0.15).collect();
    let bias: Vec<f64> = (0..CHANNELS).map(|_| 0.35 + rng.uniform() * 0.3).collect();
    // 2-3 colored Gaussian blobs
    let n_blobs = 2 + rng.below(2);
    let blobs: Vec<(f64, f64, f64, Vec<f64>)> = (0..n_blobs)
        .map(|_| {
            let cx = rng.uniform() * SIDE as f64;
            let cy = rng.uniform() * SIDE as f64;
            let sigma = 1.5 + rng.uniform() * 3.0;
            let amp: Vec<f64> = (0..CHANNELS).map(|_| rng.normal() * 0.4).collect();
            (cx, cy, sigma, amp)
        })
        .collect();
    for r in 0..SIDE {
        for c in 0..SIDE {
            for ch in 0..CHANNELS {
                let mut v = bias[ch]
                    + gx[ch] * (c as f64 / SIDE as f64 - 0.5)
                    + gy[ch] * (r as f64 / SIDE as f64 - 0.5);
                for (cx, cy, sigma, amp) in &blobs {
                    let d2 = (c as f64 - cx).powi(2) + (r as f64 - cy).powi(2);
                    v += amp[ch] * (-d2 / (2.0 * sigma * sigma)).exp();
                }
                base[(r * SIDE + c) * CHANNELS + ch] = v;
            }
        }
    }
    Prototype { base }
}

fn draw_sample(rng: &mut Rng, proto: &Prototype, img: &mut [f32]) {
    let gain = 0.85 + rng.uniform() * 0.3;
    let shift = rng.normal() * 0.04;
    for (o, b) in img.iter_mut().zip(&proto.base) {
        let noisy = b * gain + shift + rng.normal() * 0.06;
        *o = snap_to_grid(noisy, 8);
    }
}

/// Generate the dataset with a fixed train/test split.
pub fn generate(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xc1fa_0002);
    let protos: Vec<Prototype> = (0..CLASSES).map(|_| make_prototype(&mut rng)).collect();
    let make = |n: usize, rng: &mut Rng| {
        let mut xs = vec![0.0f32; n * DIM];
        let mut ys = vec![0.0f32; n];
        for i in 0..n {
            let class = i % CLASSES; // balanced
            draw_sample(rng, &protos[class], &mut xs[i * DIM..(i + 1) * DIM]);
            ys[i] = class as f32;
        }
        (xs, ys)
    };
    let (tx, ty) = make(n_train, &mut rng);
    let (ex, ey) = make(n_test, &mut rng);
    Dataset::new(
        "synth_cifar",
        vec![SIDE, SIDE, CHANNELS],
        vec![],
        tx,
        ty,
        ex,
        ey,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Split;

    #[test]
    fn on_8bit_grid_and_in_range() {
        let d = generate(40, 10, 0);
        let b = d.gather(Split::Train, &(0..40).collect::<Vec<_>>());
        for v in b.x.data() {
            assert!((0.0..=1.0).contains(v));
            let lv = v * 255.0;
            assert!((lv - lv.round()).abs() < 1e-4, "off-grid value {v}");
        }
    }

    #[test]
    fn shapes() {
        let d = generate(20, 20, 1);
        assert_eq!(d.x_shape, vec![16, 16, 3]);
        let b = d.gather(Split::Test, &[0, 1, 2]);
        assert_eq!(b.x.shape(), &[3, 16, 16, 3]);
    }

    #[test]
    fn nearest_prototype_classifier_beats_chance() {
        // Classes must carry enough signal that a trivial nearest-mean
        // classifier fit on train generalizes to test far above 10% chance.
        let d = generate(400, 100, 2);
        let tr = d.gather(Split::Train, &(0..400).collect::<Vec<_>>());
        let te = d.gather(Split::Test, &(0..100).collect::<Vec<_>>());
        let mut means = vec![vec![0.0f64; DIM]; CLASSES];
        let mut counts = vec![0usize; CLASSES];
        for i in 0..400 {
            let cls = tr.y.data()[i] as usize;
            counts[cls] += 1;
            for j in 0..DIM {
                means[cls][j] += tr.x.data()[i * DIM + j] as f64;
            }
        }
        for (m, c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= *c as f64;
            }
        }
        let mut correct = 0;
        for i in 0..100 {
            let x = &te.x.data()[i * DIM..(i + 1) * DIM];
            let pred = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f64 = x.iter().zip(&means[a]).map(|(v, m)| (*v as f64 - m).powi(2)).sum();
                    let db: f64 = x.iter().zip(&means[b]).map(|(v, m)| (*v as f64 - m).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == te.y.data()[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 60, "nearest-mean only {correct}/100");
    }
}
