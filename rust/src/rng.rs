//! Deterministic PRNG utilities (SplitMix64 core) used by the synthetic
//! datasets, the accsim reordering study and the Fig. 3 weight sampling.
//!
//! Self-contained so every experiment is bit-reproducible from a seed, with
//! no dependence on platform RNG or crate version churn.

/// SplitMix64: tiny, fast, full-period 2^64 generator. Good enough for
/// synthetic data and permutation sampling; not for cryptography.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child stream (stable: derived from the label and parent state).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
