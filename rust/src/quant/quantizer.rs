//! The weight-quantizer abstraction: paper A2Q (Eq. 20-23) and A2Q+
//! (arXiv 2401.10432, zero-centered weights) behind one trait, shared by
//! [`crate::model::QNetwork`] synthesis, the native training backend
//! (forward *and* STE backward) and the export/audit path.
//!
//! Contract every impl must keep:
//!
//! * **Guarantee** — every quantized row satisfies Eq. 15
//!   ([`crate::quant::a2q::row_satisfies_cap`]) at its (N, P), so exported
//!   layers pass the coordinator audit unchanged no matter which quantizer
//!   trained them.
//! * **Bit-exactness** — [`A2qQuantizer`] *is* the paper quantizer: its
//!   forward delegates to [`a2q_quantize_row`], and a property test in
//!   `tests/property_invariants.rs` pins the two together across random
//!   shapes and bit widths.
//! * **Norm monotonicity** — [`A2qPlusQuantizer`] never spends more integer
//!   l1 norm than plain A2Q does on the same `(v, d, t)` leaves: its norm
//!   budget is the minimum of the Eq. 23 ceiling and the plain-A2Q achieved
//!   norm, so sparsity/l1 comparisons between the two are monotone by
//!   construction. The *improved* zero-centered cap of the A2Q+ paper is
//!   exposed separately as [`crate::quant::a2q::l1_cap_plus`] for the
//!   bounds/report path; the quantizer itself keeps the conservative Eq. 15
//!   budget so the unchanged audit stays meaningful.
//!
//! The backward halves implement the straight-through estimator the L2 JAX
//! models use: round-toward-zero is treated as identity inside the M-bit
//! rails and zero outside, while the weight-norm parametrization
//! `w = g * v / ||v||_1` (and the `g = 2^min(T, t)` budget) is
//! differentiated exactly, so the per-channel `d`/`t` leaves train by
//! gradient in the native backend.

use super::a2q::a2q_quantize_row;

const LN2: f32 = std::f32::consts::LN_2;

/// One weight quantizer: forward (codes + scale) and STE backward.
pub trait WeightQuantizer: Sync {
    fn name(&self) -> &'static str;

    /// Quantize one output channel's direction vector `v` with per-channel
    /// log2-scale `d` and log2-norm target `t` into M-bit integer codes
    /// (carried in f32) plus the channel scale.
    #[allow(clippy::too_many_arguments)]
    fn quantize_row(
        &self,
        v: &[f32],
        d: f32,
        t: f32,
        m_bits: u32,
        n_bits: u32,
        p_bits: u32,
        x_signed: bool,
    ) -> (Vec<f32>, f32);

    /// STE backward through [`Self::quantize_row`]: given `dL/d(wq)` for the
    /// dequantized weights `wq = w_int * s`, write `dL/dv` into `grad_v`
    /// (overwritten, same length as `v`) and return `(dL/dd, dL/dt)`.
    #[allow(clippy::too_many_arguments)]
    fn grad_row(
        &self,
        v: &[f32],
        d: f32,
        t: f32,
        m_bits: u32,
        n_bits: u32,
        p_bits: u32,
        x_signed: bool,
        g_wq: &[f32],
        grad_v: &mut [f32],
    ) -> (f32, f32);
}

/// Paper A2Q (Eq. 20-23): forward delegates to [`a2q_quantize_row`].
pub struct A2qQuantizer;

/// A2Q+ (arXiv 2401.10432): zero-center the direction vector before the
/// same Eq. 20-23 transform, with the norm budget additionally capped at
/// the plain-A2Q achieved integer norm (see module docs).
pub struct A2qPlusQuantizer;

/// Resolve the quantizer for a training-algorithm name (`"a2q"` /
/// `"a2q_plus"`); `"qat"`/`"float"` have no accumulator-aware quantizer.
pub fn quantizer_for_alg(alg: &str) -> Option<&'static dyn WeightQuantizer> {
    match alg {
        "a2q" => Some(&A2qQuantizer),
        "a2q_plus" => Some(&A2qPlusQuantizer),
        _ => None,
    }
}

/// The per-channel quantizer-parameter initialization rules (the same ones
/// `layers._with_qparams` applies at model build): given one channel's
/// float weights, `d = log2(max|v| / (2^(M-1)-1))` puts the largest weight
/// at the top of the M-bit grid and `t = log2(||v||_1)` starts the norm
/// target at the current norm. Shared by native-backend init and the
/// float-warmup recalibration so the two can never drift apart.
pub fn init_qparams_row(row: &[f32], m_bits: u32) -> (f32, f32) {
    let vmax = (2f32.powi(m_bits as i32 - 1) - 1.0).max(1.0);
    let max_abs = row.iter().fold(0f32, |a, x| a.max(x.abs())).max(1e-8);
    let l1 = row.iter().map(|x| x.abs()).sum::<f32>().max(1e-8);
    ((max_abs / vmax).log2(), l1.log2())
}

/// The shared Eq. 20-23 geometry of one channel: scale, the Eq. 23
/// accumulator ceiling `T`, the norm budget `g = 2^min(T, t)` and the M-bit
/// code rails. Arithmetic mirrors [`a2q_quantize_row`] exactly.
struct Geom {
    s: f32,
    t_cap: f32,
    g: f32,
    lo: f32,
    hi: f32,
}

fn geom(d: f32, t: f32, m_bits: u32, n_bits: u32, p_bits: u32, x_signed: bool) -> Geom {
    let s = 2f32.powf(d);
    let sig: f32 = if x_signed { 1.0 } else { 0.0 };
    let t_cap = sig + (2f32.powf(p_bits as f32 - 1.0) - 1.0).log2() + d - n_bits as f32;
    let g = 2f32.powf(t_cap.min(t));
    let hi = 2f32.powf(m_bits as f32 - 1.0) - 1.0;
    let lo = -(2f32.powf(m_bits as f32 - 1.0));
    Geom { s, t_cap, g, lo, hi }
}

/// `sign(x)` with `sign(0) = 0` (f32's `signum` maps +0 to +1).
fn sign0(x: f32) -> f32 {
    if x == 0.0 {
        0.0
    } else {
        x.signum()
    }
}

/// The masked STE gradient core over one (possibly centered) direction row:
/// elements whose truncated code lands inside the rails pass gradient
/// straight through to `w_cont = g * v / ||v||_1` (differentiated exactly
/// through the norm), clamped elements route gradient to the scale (`d`).
///
/// Writes `dL/dv` and returns `(dot_gw, gd_clamp)` where `dot_gw` is
/// `sum_unclamped g_wq_i * w_cont_i` (so `dL/dg = dot_gw / g`) and
/// `gd_clamp` the clamped elements' `dL/dd` contribution.
fn masked_ste_grads(
    vrow: &[f32],
    g: f32,
    s: f32,
    lo: f32,
    hi: f32,
    g_wq: &[f32],
    grad_v: &mut [f32],
) -> (f32, f32) {
    let l1: f32 = vrow.iter().map(|x| x.abs()).sum();
    let l1 = if l1 == 0.0 { 1.0 } else { l1 };
    let mut dot_gw = 0.0f32;
    let mut gd_clamp = 0.0f32;
    for i in 0..vrow.len() {
        let w_cont = g * vrow[i] / l1;
        let u = (w_cont / s).trunc();
        if u < lo || u > hi {
            // clamped to a rail: w_q = s * rail, so d/dd = ln2 * w_q
            gd_clamp += g_wq[i] * u.clamp(lo, hi) * s * LN2;
        } else {
            dot_gw += g_wq[i] * w_cont;
        }
    }
    // d w_cont_i / d v_j = g (delta_ij / l1 - v_i sign(v_j) / l1^2)
    for j in 0..vrow.len() {
        let w_cont = g * vrow[j] / l1;
        let u = (w_cont / s).trunc();
        let direct = if u >= lo && u <= hi { g_wq[j] * g / l1 } else { 0.0 };
        grad_v[j] = direct - sign0(vrow[j]) * dot_gw / l1;
    }
    (dot_gw, gd_clamp)
}

impl WeightQuantizer for A2qQuantizer {
    fn name(&self) -> &'static str {
        "a2q"
    }

    fn quantize_row(
        &self,
        v: &[f32],
        d: f32,
        t: f32,
        m_bits: u32,
        n_bits: u32,
        p_bits: u32,
        x_signed: bool,
    ) -> (Vec<f32>, f32) {
        a2q_quantize_row(v, d, t, m_bits, n_bits, p_bits, x_signed)
    }

    fn grad_row(
        &self,
        v: &[f32],
        d: f32,
        t: f32,
        m_bits: u32,
        n_bits: u32,
        p_bits: u32,
        x_signed: bool,
        g_wq: &[f32],
        grad_v: &mut [f32],
    ) -> (f32, f32) {
        let gm = geom(d, t, m_bits, n_bits, p_bits, x_signed);
        if !gm.g.is_finite() || gm.g <= 0.0 || !gm.s.is_finite() || gm.s <= 0.0 {
            grad_v.fill(0.0);
            return (0.0, 0.0);
        }
        let (dot_gw, mut gd) = masked_ste_grads(v, gm.g, gm.s, gm.lo, gm.hi, g_wq, grad_v);
        // dL/dg * dg/d{t,d}: g = 2^t when t binds, 2^(const + d) otherwise,
        // so the contribution is dot_gw * ln2 on whichever leaf binds.
        let mut gt = 0.0;
        if t <= gm.t_cap {
            gt = dot_gw * LN2;
        } else {
            gd += dot_gw * LN2;
        }
        (gd, gt)
    }
}

impl WeightQuantizer for A2qPlusQuantizer {
    fn name(&self) -> &'static str {
        "a2q_plus"
    }

    fn quantize_row(
        &self,
        v: &[f32],
        d: f32,
        t: f32,
        m_bits: u32,
        n_bits: u32,
        p_bits: u32,
        x_signed: bool,
    ) -> (Vec<f32>, f32) {
        let (w_base, s) = a2q_quantize_row(v, d, t, m_bits, n_bits, p_bits, x_signed);
        let k = v.len();
        if k == 0 {
            return (w_base, s);
        }
        let l1_base: f32 = w_base.iter().map(|w| w.abs()).sum();
        let mu = v.iter().sum::<f32>() / k as f32;
        let vc: Vec<f32> = v.iter().map(|x| x - mu).collect();
        let gm = geom(d, t, m_bits, n_bits, p_bits, x_signed);
        // Budget: the Eq. 23 ceiling, additionally capped at the plain-A2Q
        // achieved integer norm (in weight units), so the centered row can
        // never spend more norm than the baseline it improves on.
        let g = gm.g.min(l1_base * gm.s);
        let l1c: f32 = vc.iter().map(|x| x.abs()).sum();
        let l1c = if l1c == 0.0 { 1.0 } else { l1c };
        let mut w: Vec<f32> = vc
            .iter()
            .map(|&x| ((g * x / l1c) / gm.s).trunc().clamp(gm.lo, gm.hi))
            .collect();
        // Exact-integer insurance against f32 round-off at the budget edge:
        // trim the largest-magnitude code (first index on ties) until the
        // integer norm is within the baseline. Rarely (if ever) more than
        // one step.
        let mut tot: f32 = w.iter().map(|x| x.abs()).sum();
        while tot > l1_base {
            let mut bi = 0usize;
            let mut bv = 0f32;
            for (i, x) in w.iter().enumerate() {
                if x.abs() > bv {
                    bv = x.abs();
                    bi = i;
                }
            }
            if bv == 0.0 {
                break;
            }
            w[bi] -= w[bi].signum();
            tot -= 1.0;
        }
        (w, s)
    }

    fn grad_row(
        &self,
        v: &[f32],
        d: f32,
        t: f32,
        m_bits: u32,
        n_bits: u32,
        p_bits: u32,
        x_signed: bool,
        g_wq: &[f32],
        grad_v: &mut [f32],
    ) -> (f32, f32) {
        let k = v.len();
        if k == 0 {
            return (0.0, 0.0);
        }
        let (w_base, _) = a2q_quantize_row(v, d, t, m_bits, n_bits, p_bits, x_signed);
        let l1_base: f32 = w_base.iter().map(|w| w.abs()).sum();
        let mu = v.iter().sum::<f32>() / k as f32;
        let vc: Vec<f32> = v.iter().map(|x| x - mu).collect();
        let gm = geom(d, t, m_bits, n_bits, p_bits, x_signed);
        let base_budget = l1_base * gm.s;
        let base_binds = base_budget < gm.g;
        let g = gm.g.min(base_budget);
        if !g.is_finite() || g <= 0.0 || !gm.s.is_finite() || gm.s <= 0.0 {
            grad_v.fill(0.0);
            return (0.0, 0.0);
        }
        let (dot_gw, mut gd) = masked_ste_grads(&vc, g, gm.s, gm.lo, gm.hi, g_wq, grad_v);
        // g = l1_base * 2^d (base binds) and g = 2^(const + d) (cap binds)
        // both differentiate to ln2 * g on d; only g = 2^t reaches t.
        let mut gt = 0.0;
        if !base_binds && t <= gm.t_cap {
            gt = dot_gw * LN2;
        } else {
            gd += dot_gw * LN2;
        }
        // Zero-centering Jacobian: v' = v - mean(v) => subtract the mean
        // gradient (gradients through the baseline's norm budget are STE'd
        // as constant, like every other integer-valued intermediate).
        let gmean = grad_v.iter().sum::<f32>() / k as f32;
        for gj in grad_v.iter_mut() {
            *gj -= gmean;
        }
        (gd, gt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::a2q::row_satisfies_cap;
    use crate::rng::Rng;

    #[test]
    fn a2q_impl_delegates_bit_exact() {
        let mut rng = Rng::new(41);
        let v: Vec<f32> = (0..97).map(|_| rng.normal() as f32).collect();
        let (a, sa) = A2qQuantizer.quantize_row(&v, -5.0, 9.0, 5, 4, 14, false);
        let (b, sb) = a2q_quantize_row(&v, -5.0, 9.0, 5, 4, 14, false);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }

    #[test]
    fn plus_rows_satisfy_cap_and_never_exceed_base_norm() {
        let mut rng = Rng::new(7);
        for trial in 0..200 {
            let k = 1 + rng.below(300);
            let v: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 2.0).collect();
            let d = -7.0 + rng.uniform() as f32 * 5.0;
            let t = -2.0 + rng.uniform() as f32 * 14.0;
            let m = 3 + (trial % 6) as u32;
            let n = 1 + (trial % 8) as u32;
            let p = 6 + (trial % 18) as u32;
            let signed = trial % 2 == 0;
            let (wb, _) = A2qQuantizer.quantize_row(&v, d, t, m, n, p, signed);
            let (wp, _) = A2qPlusQuantizer.quantize_row(&v, d, t, m, n, p, signed);
            assert!(row_satisfies_cap(&wp, p, n, signed), "trial {trial}");
            let l1b: f32 = wb.iter().map(|x| x.abs()).sum();
            let l1p: f32 = wp.iter().map(|x| x.abs()).sum();
            assert!(l1p <= l1b, "trial {trial}: plus {l1p} > base {l1b}");
            // codes stay inside the M-bit rails
            let hi = 2f32.powi(m as i32 - 1) - 1.0;
            assert!(wp.iter().all(|w| *w >= -hi - 1.0 && *w <= hi), "trial {trial}");
        }
    }

    /// Central-difference check of the STE surrogate the backward claims to
    /// differentiate: `f(v, d, t) = sum_i gw_i * wq_ste_i`, where `wq_ste`
    /// is `w_cont` inside the rails and `s * rail` outside. Parameters are
    /// chosen away from branch boundaries so the surrogate is smooth at the
    /// probe scale.
    #[test]
    fn a2q_grad_matches_numeric_surrogate() {
        let v = vec![0.9f32, -0.55, 0.3, -0.15, 0.7, 0.05];
        let gw = vec![0.3f32, -0.8, 0.5, 0.2, -0.1, 0.4];
        let (m, n, p, signed) = (6u32, 4u32, 12u32, false);

        let surrogate = |v: &[f32], d: f32, t: f32| -> f64 {
            let gm = geom(d, t, m, n, p, signed);
            let l1: f32 = v.iter().map(|x| x.abs()).sum();
            let l1 = if l1 == 0.0 { 1.0 } else { l1 };
            let mut acc = 0.0f64;
            for i in 0..v.len() {
                let w_cont = gm.g * v[i] / l1;
                let u = (w_cont / gm.s).trunc();
                let wq_ste =
                    if u < gm.lo || u > gm.hi { u.clamp(gm.lo, gm.hi) * gm.s } else { w_cont };
                acc += (gw[i] * wq_ste) as f64;
            }
            acc
        };

        // one t-binding and one cap-binding configuration
        for (d, t) in [(-4.0f32, 1.5f32), (-4.0, 30.0)] {
            let mut gv = vec![0.0f32; v.len()];
            let (gd, gt) = A2qQuantizer.grad_row(&v, d, t, m, n, p, signed, &gw, &mut gv);
            let h = 1e-3f32;
            let nd = (surrogate(&v, d + h, t) - surrogate(&v, d - h, t)) / (2.0 * h as f64);
            let nt = (surrogate(&v, d, t + h) - surrogate(&v, d, t - h)) / (2.0 * h as f64);
            assert!((gd as f64 - nd).abs() < 2e-2, "d={d} t={t}: gd {gd} vs {nd}");
            assert!((gt as f64 - nt).abs() < 2e-2, "d={d} t={t}: gt {gt} vs {nt}");
            for j in 0..v.len() {
                let mut vp = v.clone();
                let mut vm = v.clone();
                vp[j] += h;
                vm[j] -= h;
                let nv = (surrogate(&vp, d, t) - surrogate(&vm, d, t)) / (2.0 * h as f64);
                assert!(
                    (gv[j] as f64 - nv).abs() < 2e-2,
                    "d={d} t={t} v[{j}]: {} vs {nv}",
                    gv[j]
                );
            }
        }
    }

    #[test]
    fn plus_grads_are_mean_free_over_v() {
        let mut rng = Rng::new(99);
        let v: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let gw: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let mut gv = vec![0.0f32; 64];
        let (gd, gt) = A2qPlusQuantizer.grad_row(&v, -5.0, 8.0, 4, 4, 16, false, &gw, &mut gv);
        assert!(gd.is_finite() && gt.is_finite());
        let mean: f32 = gv.iter().sum::<f32>() / 64.0;
        assert!(mean.abs() < 1e-5, "centered quantizer gradient must be mean-free: {mean}");
    }

    #[test]
    fn quantizer_lookup_by_alg() {
        assert_eq!(quantizer_for_alg("a2q").unwrap().name(), "a2q");
        assert_eq!(quantizer_for_alg("a2q_plus").unwrap().name(), "a2q_plus");
        assert!(quantizer_for_alg("qat").is_none());
        assert!(quantizer_for_alg("float").is_none());
    }

    #[test]
    fn zero_vector_rows_are_stable() {
        let v = vec![0.0f32; 16];
        let gw = vec![1.0f32; 16];
        for q in [&A2qQuantizer as &dyn WeightQuantizer, &A2qPlusQuantizer] {
            let (w, _) = q.quantize_row(&v, -4.0, 2.0, 8, 8, 16, false);
            assert!(w.iter().all(|x| *x == 0.0), "{}", q.name());
            let mut gv = vec![0.0f32; 16];
            let (gd, gt) = q.grad_row(&v, -4.0, 2.0, 8, 8, 16, false, &gw, &mut gv);
            assert!(gd.is_finite() && gt.is_finite(), "{}", q.name());
            assert!(gv.iter().all(|x| x.is_finite()), "{}", q.name());
        }
    }
}
