//! Quantization math on the Rust side: the paper's accumulator bit-width
//! bounds (§3), a bit-exact mirror of the A2Q quantizer used for verifying
//! exported artifacts, the [`quantizer::WeightQuantizer`] abstraction (paper
//! A2Q and A2Q+ behind one trait, with STE backward halves for the native
//! training backend), and integer-tensor helpers.

pub mod a2q;
pub mod bounds;
pub mod qtensor;
pub mod quantizer;

pub use a2q::{a2q_quantize_row, l1_cap, l1_cap_plus};
pub use bounds::{data_type_bound, weight_bound, DotShape};
pub use qtensor::QTensor;
pub use quantizer::{quantizer_for_alg, A2qPlusQuantizer, A2qQuantizer, WeightQuantizer};
