//! Quantization math on the Rust side: the paper's accumulator bit-width
//! bounds (§3), a bit-exact mirror of the A2Q quantizer used for verifying
//! exported artifacts, and integer-tensor helpers.

pub mod a2q;
pub mod bounds;
pub mod qtensor;

pub use a2q::{a2q_quantize_row, l1_cap};
pub use bounds::{data_type_bound, weight_bound, DotShape};
pub use qtensor::QTensor;
