//! Integer tensors at the deployment boundary: exported weights arrive as
//! f32 literals carrying exact small integers (the artifact interface is
//! all-f32); `QTensor` re-types them as i64 with their scales so the accsim
//! and FINN substrates work in the true integer domain.

use crate::tensor::Tensor;

/// A per-channel-quantized 2-D integer tensor `[c_out, k]` with scales.
#[derive(Clone, Debug)]
pub struct QTensor {
    /// Integer codes, row-major `[c_out, k]`.
    pub codes: Vec<i64>,
    /// Per-output-channel scale factors, length `c_out`.
    pub scales: Vec<f32>,
    /// Per-output-channel float biases, length `c_out` (applied post-dequant).
    pub bias: Vec<f32>,
    pub c_out: usize,
    pub k: usize,
}

impl QTensor {
    /// Assemble from the export-artifact triple (w_int [C,K], s [C,1], b [C]).
    ///
    /// In-process callers holding tensors they just produced may keep this
    /// panicking path; anything ingesting *external* exports (files, serve
    /// requests) must go through [`Self::try_from_export`] so malformed
    /// data becomes a typed error instead of an abort.
    pub fn from_export(w_int: &Tensor, s: &Tensor, b: &Tensor) -> Self {
        let c_out = w_int.shape()[0];
        let k = w_int.shape()[1];
        assert_eq!(s.len(), c_out, "scale count mismatch");
        assert_eq!(b.len(), c_out, "bias count mismatch");
        QTensor {
            codes: w_int.to_i64(),
            scales: s.data().to_vec(),
            bias: b.data().to_vec(),
            c_out,
            k,
        }
    }

    /// Validating twin of [`Self::from_export`] for untrusted exports:
    /// rejects non-rank-2 weights, scale/bias count mismatches, NaN/inf
    /// anywhere, non-integral weight codes (the f32 carrier must hold exact
    /// integers — a NaN would otherwise round to a silent garbage code),
    /// and non-positive scales, each with an error naming the offending
    /// element.
    pub fn try_from_export(w_int: &Tensor, s: &Tensor, b: &Tensor) -> anyhow::Result<Self> {
        anyhow::ensure!(
            w_int.shape().len() == 2,
            "weight tensor must be rank-2 [c_out, k], got shape {:?}",
            w_int.shape()
        );
        let c_out = w_int.shape()[0];
        let k = w_int.shape()[1];
        anyhow::ensure!(c_out > 0 && k > 0, "degenerate weight shape [{c_out}, {k}]");
        anyhow::ensure!(s.len() == c_out, "{} scales for {} channels", s.len(), c_out);
        anyhow::ensure!(b.len() == c_out, "{} biases for {} channels", b.len(), c_out);
        for (i, v) in w_int.data().iter().enumerate() {
            anyhow::ensure!(
                v.is_finite() && *v == v.round(),
                "weight code at [{}, {}] is not a finite integer: {v}",
                i / k,
                i % k
            );
        }
        for (c, v) in s.data().iter().enumerate() {
            anyhow::ensure!(
                v.is_finite() && *v > 0.0,
                "scale for channel {c} must be finite and positive, got {v}"
            );
        }
        for (c, v) in b.data().iter().enumerate() {
            anyhow::ensure!(v.is_finite(), "bias for channel {c} is not finite: {v}");
        }
        Ok(QTensor {
            codes: w_int.to_i64(),
            scales: s.data().to_vec(),
            bias: b.data().to_vec(),
            c_out,
            k,
        })
    }

    /// Row `c` of integer codes.
    pub fn row(&self, c: usize) -> &[i64] {
        &self.codes[c * self.k..(c + 1) * self.k]
    }

    /// Per-channel l1 norms of the integer codes (`||w||_1`, Eq. 13).
    pub fn row_l1(&self) -> Vec<i64> {
        (0..self.c_out)
            .map(|c| self.row(c).iter().map(|w| w.abs()).sum())
            .collect()
    }

    /// Largest per-channel l1 norm (sets the layer's weight-norm bound).
    pub fn max_l1(&self) -> i64 {
        self.row_l1().into_iter().max().unwrap_or(0)
    }

    /// Fraction of zero codes (unstructured sparsity, paper §5.2.1).
    pub fn sparsity(&self) -> f64 {
        if self.codes.is_empty() {
            return 0.0;
        }
        let z = self.codes.iter().filter(|w| **w == 0).count();
        z as f64 / self.codes.len() as f64
    }

    /// Maximum absolute code (how much of the M-bit range is used).
    pub fn max_abs_code(&self) -> i64 {
        self.codes.iter().map(|w| w.abs()).max().unwrap_or(0)
    }

    /// Dequantize row `c` to f32 (codes * scale).
    pub fn dequant_row(&self, c: usize) -> Vec<f32> {
        let s = self.scales[c];
        self.row(c).iter().map(|w| *w as f32 * s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> QTensor {
        let w = Tensor::new(vec![2, 3], vec![1.0, -2.0, 0.0, 3.0, 0.0, 0.0]);
        let s = Tensor::new(vec![2, 1], vec![0.5, 0.25]);
        let b = Tensor::from_vec(vec![0.1, -0.1]);
        QTensor::from_export(&w, &s, &b)
    }

    #[test]
    fn l1_and_sparsity() {
        let q = sample();
        assert_eq!(q.row_l1(), vec![3, 3]);
        assert_eq!(q.max_l1(), 3);
        assert_eq!(q.sparsity(), 0.5);
        assert_eq!(q.max_abs_code(), 3);
    }

    #[test]
    fn dequant() {
        let q = sample();
        assert_eq!(q.dequant_row(0), vec![0.5, -1.0, 0.0]);
        assert_eq!(q.dequant_row(1), vec![0.75, 0.0, 0.0]);
    }

    #[test]
    fn try_from_export_accepts_the_valid_triple_and_matches_the_panicking_path() {
        let w = Tensor::new(vec![2, 3], vec![1.0, -2.0, 0.0, 3.0, 0.0, 0.0]);
        let s = Tensor::new(vec![2, 1], vec![0.5, 0.25]);
        let b = Tensor::from_vec(vec![0.1, -0.1]);
        let q = QTensor::try_from_export(&w, &s, &b).unwrap();
        let p = QTensor::from_export(&w, &s, &b);
        assert_eq!(q.codes, p.codes);
        assert_eq!(q.scales, p.scales);
        assert_eq!(q.bias, p.bias);
        assert_eq!((q.c_out, q.k), (2, 3));
    }

    #[test]
    fn try_from_export_rejects_malformed_triples_with_descriptive_errors() {
        let w = Tensor::new(vec![2, 3], vec![1.0, -2.0, 0.0, 3.0, 0.0, 0.0]);
        let s = Tensor::new(vec![2, 1], vec![0.5, 0.25]);
        let b = Tensor::from_vec(vec![0.1, -0.1]);
        let cases: Vec<(Tensor, Tensor, Tensor, &str)> = vec![
            // rank-1 weights
            (Tensor::from_vec(vec![1.0; 6]), s.clone(), b.clone(), "rank-2"),
            // NaN weight code
            (
                Tensor::new(vec![2, 3], vec![1.0, f32::NAN, 0.0, 3.0, 0.0, 0.0]),
                s.clone(),
                b.clone(),
                "finite integer",
            ),
            // non-integral weight code
            (
                Tensor::new(vec![2, 3], vec![1.0, 0.5, 0.0, 3.0, 0.0, 0.0]),
                s.clone(),
                b.clone(),
                "finite integer",
            ),
            // scale count mismatch
            (w.clone(), Tensor::from_vec(vec![0.5]), b.clone(), "scales for"),
            // infinite scale
            (
                w.clone(),
                Tensor::new(vec![2, 1], vec![0.5, f32::INFINITY]),
                b.clone(),
                "finite and positive",
            ),
            // zero scale
            (w.clone(), Tensor::new(vec![2, 1], vec![0.5, 0.0]), b.clone(), "finite and positive"),
            // bias count mismatch
            (w.clone(), s.clone(), Tensor::from_vec(vec![0.1]), "biases for"),
            // NaN bias
            (w.clone(), s.clone(), Tensor::from_vec(vec![0.1, f32::NAN]), "not finite"),
        ];
        for (w, s, b, needle) in cases {
            let err = QTensor::try_from_export(&w, &s, &b).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "error {msg:?} should mention {needle:?}");
        }
    }
}
