//! Integer tensors at the deployment boundary: exported weights arrive as
//! f32 literals carrying exact small integers (the artifact interface is
//! all-f32); `QTensor` re-types them as i64 with their scales so the accsim
//! and FINN substrates work in the true integer domain.

use crate::tensor::Tensor;

/// A per-channel-quantized 2-D integer tensor `[c_out, k]` with scales.
#[derive(Clone, Debug)]
pub struct QTensor {
    /// Integer codes, row-major `[c_out, k]`.
    pub codes: Vec<i64>,
    /// Per-output-channel scale factors, length `c_out`.
    pub scales: Vec<f32>,
    /// Per-output-channel float biases, length `c_out` (applied post-dequant).
    pub bias: Vec<f32>,
    pub c_out: usize,
    pub k: usize,
}

impl QTensor {
    /// Assemble from the export-artifact triple (w_int [C,K], s [C,1], b [C]).
    pub fn from_export(w_int: &Tensor, s: &Tensor, b: &Tensor) -> Self {
        let c_out = w_int.shape()[0];
        let k = w_int.shape()[1];
        assert_eq!(s.len(), c_out, "scale count mismatch");
        assert_eq!(b.len(), c_out, "bias count mismatch");
        QTensor {
            codes: w_int.to_i64(),
            scales: s.data().to_vec(),
            bias: b.data().to_vec(),
            c_out,
            k,
        }
    }

    /// Row `c` of integer codes.
    pub fn row(&self, c: usize) -> &[i64] {
        &self.codes[c * self.k..(c + 1) * self.k]
    }

    /// Per-channel l1 norms of the integer codes (`||w||_1`, Eq. 13).
    pub fn row_l1(&self) -> Vec<i64> {
        (0..self.c_out)
            .map(|c| self.row(c).iter().map(|w| w.abs()).sum())
            .collect()
    }

    /// Largest per-channel l1 norm (sets the layer's weight-norm bound).
    pub fn max_l1(&self) -> i64 {
        self.row_l1().into_iter().max().unwrap_or(0)
    }

    /// Fraction of zero codes (unstructured sparsity, paper §5.2.1).
    pub fn sparsity(&self) -> f64 {
        if self.codes.is_empty() {
            return 0.0;
        }
        let z = self.codes.iter().filter(|w| **w == 0).count();
        z as f64 / self.codes.len() as f64
    }

    /// Maximum absolute code (how much of the M-bit range is used).
    pub fn max_abs_code(&self) -> i64 {
        self.codes.iter().map(|w| w.abs()).max().unwrap_or(0)
    }

    /// Dequantize row `c` to f32 (codes * scale).
    pub fn dequant_row(&self, c: usize) -> Vec<f32> {
        let s = self.scales[c];
        self.row(c).iter().map(|w| *w as f32 * s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> QTensor {
        let w = Tensor::new(vec![2, 3], vec![1.0, -2.0, 0.0, 3.0, 0.0, 0.0]);
        let s = Tensor::new(vec![2, 1], vec![0.5, 0.25]);
        let b = Tensor::from_vec(vec![0.1, -0.1]);
        QTensor::from_export(&w, &s, &b)
    }

    #[test]
    fn l1_and_sparsity() {
        let q = sample();
        assert_eq!(q.row_l1(), vec![3, 3]);
        assert_eq!(q.max_l1(), 3);
        assert_eq!(q.sparsity(), 0.5);
        assert_eq!(q.max_abs_code(), 3);
    }

    #[test]
    fn dequant() {
        let q = sample();
        assert_eq!(q.dequant_row(0), vec![0.5, -1.0, 0.0]);
        assert_eq!(q.dequant_row(1), vec![0.75, 0.0, 0.0]);
    }
}
