//! Bit-exact Rust mirror of the A2Q weight quantizer (paper Eq. 20-23).
//!
//! The authoritative implementation is the L1 Pallas kernel
//! (`python/compile/kernels/a2q.py`); this mirror exists so the Rust side can
//! (a) independently verify exported integer weights without a PJRT round
//! trip, and (b) drive pure-Rust property tests over the guarantee. The two
//! implementations are cross-checked through the export artifacts in the
//! integration tests.

/// Upper bound on the integer-weight l1 norm for a P-bit accumulator fed by
/// N-bit inputs (Eq. 15): `(2^(P-1) - 1) * 2^(1_signed(x) - N)`.
pub fn l1_cap(p_bits: u32, n_bits: u32, x_signed: bool) -> f64 {
    let sig = if x_signed { 1.0 } else { 0.0 };
    (2f64.powi(p_bits as i32 - 1) - 1.0) * 2f64.powf(sig - n_bits as f64)
}

/// A2Q+ (arXiv 2401.10432) improved l1 cap for **zero-centered** weight
/// rows. Centering makes the worst-case accumulation range symmetric —
/// positive and negative code mass each carry half the norm — so a signed
/// P-bit register affords `(2^P - 2) / (2^N - 1)` for unsigned N-bit inputs
/// (and `(2^P - 2) / 2^(N-1)` signed): slightly more than double the Eq. 15
/// budget at the same P. This is the *reporting/bounds* cap; the
/// [`crate::quant::quantizer::A2qPlusQuantizer`] deliberately keeps the
/// conservative Eq. 15 budget so every exported row still passes
/// [`row_satisfies_cap`] and the audit stays quantizer-independent.
pub fn l1_cap_plus(p_bits: u32, n_bits: u32, x_signed: bool) -> f64 {
    let num = 2f64.powi(p_bits as i32) - 2.0;
    if x_signed {
        num / 2f64.powi(n_bits as i32 - 1)
    } else {
        num / (2f64.powi(n_bits as i32) - 1.0)
    }
}

/// Quantize one output channel's direction vector `v` with per-channel
/// log2-scale `d` and log2-norm `t` (Eq. 20-23). Returns (w_int, s).
///
/// All arithmetic in f32 to match the XLA artifact bit-for-bit.
pub fn a2q_quantize_row(
    v: &[f32],
    d: f32,
    t: f32,
    m_bits: u32,
    n_bits: u32,
    p_bits: u32,
    x_signed: bool,
) -> (Vec<f32>, f32) {
    let s = 2f32.powf(d);
    let sig: f32 = if x_signed { 1.0 } else { 0.0 };
    // T = 1_signed(x) + log2(2^(P-1) - 1) + d - N        (Eq. 23)
    let cap = sig + (2f32.powf(p_bits as f32 - 1.0) - 1.0).log2() + d - n_bits as f32;
    let g = 2f32.powf(cap.min(t));
    let l1: f32 = v.iter().map(|x| x.abs()).sum();
    let l1 = if l1 == 0.0 { 1.0 } else { l1 };
    let lo = -(2f32.powf(m_bits as f32 - 1.0));
    let hi = 2f32.powf(m_bits as f32 - 1.0) - 1.0;
    let w_int: Vec<f32> = v
        .iter()
        .map(|&x| {
            let w_cont = g * x / l1;
            (w_cont / s).trunc().clamp(lo, hi) // round-toward-zero then clip
        })
        .collect();
    (w_int, s)
}

/// Check Eq. 15 on a row of integer codes: the guaranteed-overflow-avoidance
/// invariant every exported A2Q layer must satisfy.
///
/// Exact integer arithmetic: the codes are integers stored in f32, so their
/// l1 norm is summed in i128 and compared against the cap
/// `(2^(P-1) - 1) * 2^(1_signed(x) - N)` by the equivalent integer test
/// `l1 <= floor((2^(P-1) - 1) / 2^(N - 1_signed(x)))` — true iff
/// `l1 * 2^(N - sig) <= 2^(P-1) - 1` since `l1` is an integer. No float
/// round-off, no epsilon fudge: a row exactly at the cap passes, one code
/// step above it fails.
pub fn row_satisfies_cap(
    w_int: &[f32],
    p_bits: u32,
    n_bits: u32,
    x_signed: bool,
) -> bool {
    debug_assert!((1..=64).contains(&p_bits), "p_bits {p_bits}");
    debug_assert!(n_bits >= u32::from(x_signed), "n_bits {n_bits} signed {x_signed}");
    let l1: i128 = w_int.iter().map(|x| x.abs() as i128).sum();
    let shift = n_bits - u32::from(x_signed);
    let acc_max = (1i128 << (p_bits - 1)) - 1;
    l1 <= acc_max >> shift.min(127)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn cap_matches_paper_numbers() {
        // P=16, N=8, unsigned: (2^15 - 1) * 2^-8 = 127.996...
        let c = l1_cap(16, 8, false);
        assert!((c - 32767.0 / 256.0).abs() < 1e-9);
        // signed input doubles the cap
        assert_eq!(l1_cap(16, 8, true), 2.0 * l1_cap(16, 8, false));
    }

    #[test]
    fn plus_cap_improves_on_eq15() {
        // unsigned: (2^16 - 2)/(2^8 - 1) = 257.003... > 2x the Eq. 15 cap
        assert!(l1_cap_plus(16, 8, false) > 2.0 * l1_cap(16, 8, false));
        // signed: exactly the factor-2 improvement
        let plus = l1_cap_plus(16, 8, true);
        assert!((plus - 2.0 * l1_cap(16, 8, true)).abs() < 1e-9, "{plus}");
        // the improved cap always dominates the conservative one
        for p in [8u32, 12, 16, 24] {
            for n in [1u32, 4, 8] {
                for signed in [false, true] {
                    assert!(l1_cap_plus(p, n, signed) > l1_cap(p, n, signed));
                }
            }
        }
    }

    #[test]
    fn cap_check_is_exact_at_the_boundary() {
        // P=16, N=8 unsigned: cap = 32767/256 = 127.996...; integer l1 127
        // passes and 128 fails, with no epsilon fudge either way.
        assert!(row_satisfies_cap(&[127.0], 16, 8, false));
        assert!(!row_satisfies_cap(&[128.0], 16, 8, false));
        // N - 1_signed = 0: the cap equals 2^(P-1) - 1 exactly, and a row
        // exactly at it passes.
        assert!(row_satisfies_cap(&[127.0], 8, 1, true));
        assert!(!row_satisfies_cap(&[128.0], 8, 1, true));
        // Large codes sum exactly in i128 (an f32 sum would lose low bits).
        let big = [16_777_216.0f32; 4]; // 2^24 each, l1 = 2^26
        assert!(row_satisfies_cap(&big, 28, 1, true));
        assert!(!row_satisfies_cap(&big, 27, 1, true));
    }

    #[test]
    fn quantized_rows_always_satisfy_cap() {
        let mut rng = Rng::new(11);
        for trial in 0..200 {
            let k = 1 + rng.below(400);
            let v: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 2.0).collect();
            let d = -6.0 + rng.uniform() as f32 * 4.0;
            let t = -2.0 + rng.uniform() as f32 * 14.0; // often far above cap
            let m = 3 + (trial % 6) as u32;
            let n = 1 + (trial % 8) as u32;
            let p = 6 + (trial % 18) as u32;
            let signed = trial % 2 == 0;
            let (w_int, _) = a2q_quantize_row(&v, d, t, m, n, p, signed);
            assert!(
                row_satisfies_cap(&w_int, p, n, signed),
                "violated at trial {trial}: k={k} m={m} n={n} p={p}"
            );
        }
    }

    #[test]
    fn codes_within_m_bits() {
        let mut rng = Rng::new(3);
        let v: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let (w_int, _) = a2q_quantize_row(&v, -8.0, 10.0, 4, 4, 24, false);
        for w in &w_int {
            assert!(*w >= -8.0 && *w <= 7.0, "4-bit signed range violated: {w}");
        }
    }

    #[test]
    fn rtz_never_rounds_up_in_magnitude() {
        let v = vec![0.9999f32, -0.9999, 0.5, -0.5];
        let (w_int, s) = a2q_quantize_row(&v, 0.0, 1.0, 8, 1, 20, false);
        // g = 2^min(T,1); l1 ~= 3; every |w_cont/s| < 1 must truncate to 0.
        for (wi, vi) in w_int.iter().zip(&v) {
            assert!(wi.abs() * s <= vi.abs() + 1e-6);
        }
    }

    #[test]
    fn zero_vector_is_fixed_point() {
        let (w_int, _) = a2q_quantize_row(&[0.0; 64], -4.0, 2.0, 8, 8, 16, false);
        assert!(w_int.iter().all(|w| *w == 0.0));
    }
}
