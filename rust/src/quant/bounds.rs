//! Accumulator bit-width lower bounds (paper §3).
//!
//! Two bounds on the accumulator width P needed to make overflow impossible
//! for a K-element dot product of N-bit inputs and M-bit signed weights:
//!
//! * **data-type bound** (Eq. 8-10) — worst case over the representation
//!   ranges alone:  `P >= alpha + phi(alpha) + 1`,
//!   `alpha = log2(K) + N + M - 1 - 1_signed(x)`.
//! * **weight-norm bound** (Eq. 12-14) — tighter, using the frozen weights:
//!   `P >= beta + phi(beta) + 1`, `beta = log2(||w||_1) + N - 1_signed(x)`.
//!
//! with `phi(a) = log2(1 + 2^-a)`. Both guarantee every *intermediate partial
//! sum* fits (the derivation bounds `sum |x_i||w_i|`, which dominates every
//! prefix), not just the final result.

/// Geometry of one dot product: K MACs of N-bit inputs times M-bit weights.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DotShape {
    /// Dot-product length (elements accumulated per output).
    pub k: usize,
    /// Weight bit width M (weights are always signed, paper §3).
    pub m_bits: u32,
    /// Input bit width N.
    pub n_bits: u32,
    /// Whether the input integers are signed.
    pub x_signed: bool,
}

fn phi(a: f64) -> f64 {
    (1.0 + 2f64.powf(-a)).log2()
}

fn sig(x_signed: bool) -> f64 {
    if x_signed {
        1.0
    } else {
        0.0
    }
}

/// Exact (real-valued) data-type lower bound on P (Eq. 8).
pub fn data_type_bound_exact(s: DotShape) -> f64 {
    let alpha =
        (s.k as f64).log2() + s.n_bits as f64 + s.m_bits as f64 - 1.0 - sig(s.x_signed);
    alpha + phi(alpha) + 1.0
}

/// Ceiling with a one-ULP-scale guard: the exact bounds hit integers
/// *exactly* at their tight points (e.g. the weight bound at the Eq. 15 cap
/// is exactly P), and f64 round-off must not push those to P + 1.
fn ceil_bits(x: f64) -> u32 {
    (x - 1e-9).ceil().max(1.0) as u32
}

/// Smallest integer accumulator width satisfying the data-type bound.
pub fn data_type_bound(s: DotShape) -> u32 {
    ceil_bits(data_type_bound_exact(s))
}

/// Exact (real-valued) weight-norm lower bound on P (Eq. 12) given the
/// l1 norm of one output channel's *integer* weights.
pub fn weight_bound_exact(l1_norm: f64, n_bits: u32, x_signed: bool) -> f64 {
    if l1_norm <= 0.0 {
        // An all-zero channel never accumulates anything; one sign bit.
        return 1.0;
    }
    let beta = l1_norm.log2() + n_bits as f64 - sig(x_signed);
    beta + phi(beta) + 1.0
}

/// Smallest integer accumulator width satisfying the weight-norm bound.
pub fn weight_bound(l1_norm: f64, n_bits: u32, x_signed: bool) -> u32 {
    ceil_bits(weight_bound_exact(l1_norm, n_bits, x_signed))
}

/// Worst-case input magnitude `2^(N - 1_signed)` (paper §3.1; the unsigned
/// case uses the paper's 2^N simplification, which keeps the guarantee).
///
/// Domain: `0 <= N - 1_signed <= 62` (an i64 holds shifts up to 62 without
/// hitting the sign bit). Out-of-domain widths saturate to `i64::MAX` — a
/// magnitude that keeps every `l1 * max|x|` safety gate conservative — with
/// a `debug_assert` so misuse is loud in debug builds instead of UB-shaped
/// (`1i64 << 63` flips the sign, silently passing gates it should fail).
pub fn max_input_mag(n_bits: u32, x_signed: bool) -> i64 {
    let shift = n_bits as i64 - i64::from(x_signed);
    debug_assert!(
        (0..=62).contains(&shift),
        "max_input_mag: N - 1_signed = {shift} outside 0..=62 (n_bits {n_bits}, signed {x_signed})"
    );
    if (0..=62).contains(&shift) {
        1i64 << shift
    } else {
        i64::MAX
    }
}

/// Largest value a signed P-bit accumulator holds: `2^(P-1) - 1`.
pub fn acc_max(p_bits: u32) -> i64 {
    (1i64 << (p_bits - 1)) - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig2_bound_is_19_bits() {
        // Appendix A: K = 784, M = 8, N = 1 unsigned -> P lower bound 19.
        let s = DotShape { k: 784, m_bits: 8, n_bits: 1, x_signed: false };
        assert_eq!(data_type_bound(s), 19);
    }

    #[test]
    fn bound_monotone_in_k_m_n() {
        let base = DotShape { k: 128, m_bits: 6, n_bits: 6, x_signed: false };
        let b = data_type_bound_exact(base);
        assert!(data_type_bound_exact(DotShape { k: 256, ..base }) > b);
        assert!(data_type_bound_exact(DotShape { m_bits: 7, ..base }) > b);
        assert!(data_type_bound_exact(DotShape { n_bits: 7, ..base }) > b);
    }

    #[test]
    fn signed_input_saves_one_bit() {
        let u = DotShape { k: 512, m_bits: 8, n_bits: 8, x_signed: false };
        let s = DotShape { x_signed: true, ..u };
        let du = data_type_bound_exact(u);
        let ds = data_type_bound_exact(s);
        assert!((du - ds - 1.0).abs() < 1e-6, "{du} vs {ds}");
    }

    #[test]
    fn weight_bound_no_looser_than_data_type_bound() {
        // The worst admissible l1 norm K * 2^(M-1) recovers the data-type case.
        let s = DotShape { k: 300, m_bits: 7, n_bits: 5, x_signed: false };
        let worst_l1 = s.k as f64 * 2f64.powi(s.m_bits as i32 - 1);
        let wb = weight_bound_exact(worst_l1, s.n_bits, s.x_signed);
        let db = data_type_bound_exact(s);
        assert!((wb - db).abs() < 1e-9, "{wb} vs {db}");
        // and any real weight draw is strictly tighter
        assert!(weight_bound_exact(worst_l1 / 4.0, s.n_bits, s.x_signed) < db);
    }

    #[test]
    fn zero_norm_channel() {
        assert_eq!(weight_bound(0.0, 8, false), 1);
    }

    #[test]
    fn max_input_mag_in_domain_and_saturating() {
        assert_eq!(max_input_mag(1, false), 2);
        assert_eq!(max_input_mag(8, false), 256);
        assert_eq!(max_input_mag(8, true), 128);
        // the widest legal shifts
        assert_eq!(max_input_mag(62, false), 1i64 << 62);
        assert_eq!(max_input_mag(63, true), 1i64 << 62);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "max_input_mag")]
    fn max_input_mag_out_of_domain_is_loud_in_debug() {
        let _ = max_input_mag(64, false);
    }

    #[test]
    fn acc_max_values() {
        assert_eq!(acc_max(8), 127);
        assert_eq!(acc_max(16), 32767);
        assert_eq!(acc_max(32), 2147483647);
    }
}
