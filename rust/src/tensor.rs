//! A minimal dense f32 tensor: the host-side currency between the runtime
//! (PJRT literals), the datasets, the simulators and the estimators.
//!
//! Deliberately tiny — row-major `Vec<f32>` plus a shape. Anything heavier
//! (views, broadcasting, autodiff) lives in XLA on the other side of the
//! artifact boundary.

use std::fmt;

/// Row-major dense f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from shape and data; panics if the element count mismatches.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} vs {} elements", data.len());
        Self { shape, data }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    /// Scalar tensor (shape `[]`).
    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    /// 1-D tensor.
    pub fn from_vec(data: Vec<f32>) -> Self {
        Self { shape: vec![data.len()], data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows for a 2-D view `[rows, cols]`; panics on other ranks.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() needs a 2-D tensor");
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() needs a 2-D tensor");
        self.shape[1]
    }

    /// Borrow row `r` of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    /// Borrow rows `r0..r1` of a 2-D tensor as one contiguous slice —
    /// the zero-copy way to hand a row range to an encoder or a
    /// per-request split without materializing a new tensor.
    pub fn rows_slice(&self, r0: usize, r1: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r0 * c..r1 * c]
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len());
        self.shape = shape;
        self
    }

    /// Scalar value of a 0-D/1-element tensor.
    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() needs exactly one element");
        self.data[0]
    }

    /// Fraction of exactly-zero elements (unstructured sparsity, paper §5.2.1).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let z = self.data.iter().filter(|v| **v == 0.0).count();
        z as f64 / self.data.len() as f64
    }

    /// l1 norm of row `r` of a 2-D tensor (per-channel `||w||_1`, Eq. 13).
    pub fn row_l1(&self, r: usize) -> f64 {
        self.row(r).iter().map(|v| v.abs() as f64).sum()
    }

    /// Round every element to the nearest integer and return as i64
    /// (used on exported integer-code tensors, which carry ints in f32).
    pub fn to_i64(&self) -> Vec<i64> {
        self.data.iter().map(|v| v.round() as i64).collect()
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{}, {}, ... x{}]", self.data[0], self.data[1], self.data.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_access() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.rows_slice(0, 2), t.data());
        assert_eq!(t.rows_slice(1, 2), &[4., 5., 6.]);
        assert!(t.rows_slice(1, 1).is_empty());
    }

    #[test]
    fn sparsity_counts_exact_zeros() {
        let t = Tensor::from_vec(vec![0.0, 1.0, 0.0, -2.0]);
        assert_eq!(t.sparsity(), 0.5);
    }

    #[test]
    fn row_l1() {
        let t = Tensor::new(vec![1, 3], vec![-1.0, 2.0, -3.0]);
        assert_eq!(t.row_l1(0), 6.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
