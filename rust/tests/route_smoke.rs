//! Router smoke tests: replica failure must be invisible to clients.
//!
//! Every test drives a real in-process [`Router`] over real `a2q serve`
//! child processes (spawned from the built CLI binary) and asserts the
//! ISSUE's contract: every client request either succeeds bit-identically
//! to a direct replica hit or fails with a typed shed code — never a
//! transport error the client didn't cause, never a torn frame, never a
//! hang. Kill -9, drain, torn replies, worker panics and whole-pool death
//! are all exercised.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use a2q::json::Json;
use a2q::serve::{
    wire, BackendSpec, LoadgenConfig, RetryPolicy, Router, RouterConfig, ServeError, WireFormat,
};

const SPEC: &str = "smoke:12x8x3:m4n4p16";

// ---------------------------------------------------------------------------
// Real `a2q serve` child processes
// ---------------------------------------------------------------------------

/// One replica process, killed on drop. `kill` is SIGKILL — the
/// unannounced death the router must absorb.
struct ServeChild {
    child: Child,
    addr: String,
}

impl ServeChild {
    fn spawn(fault: Option<&str>) -> ServeChild {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_a2q"));
        cmd.args(["serve", "--addr", "127.0.0.1:0", "--models", SPEC, "--workers", "2"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .stdin(Stdio::null());
        if let Some(f) = fault {
            cmd.env("A2Q_FAULT", f);
        }
        let mut child = cmd.spawn().expect("spawn serve child");
        let stdout = child.stdout.take().expect("child stdout");
        let mut reader = BufReader::new(stdout);
        let mut line = String::new();
        let addr = loop {
            line.clear();
            let n = reader.read_line(&mut line).expect("read child stdout");
            assert!(n > 0, "serve child exited before announcing its address");
            if let Some(rest) = line.trim().strip_prefix("[serve] listening on ") {
                break rest.trim().to_string();
            }
        };
        // Drain the rest of the child's stdout so it never blocks on a
        // full pipe.
        std::thread::spawn(move || {
            let mut sink = [0u8; 4096];
            while matches!(reader.read(&mut sink), Ok(n) if n > 0) {}
        });
        ServeChild { child, addr }
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ServeChild {
    fn drop(&mut self) {
        self.kill();
    }
}

/// A router over already-running replicas, on an ephemeral port, with
/// test-fast probing. `tweak` adjusts knobs per test.
fn router_over(addrs: &[&str], tweak: impl FnOnce(&mut RouterConfig)) -> Router {
    let specs: Vec<BackendSpec> =
        addrs.iter().map(|a| BackendSpec::Attached(a.to_string())).collect();
    let mut cfg = RouterConfig {
        addr: "127.0.0.1:0".to_string(),
        probe_interval_ms: 20,
        respawn: false,
        ..RouterConfig::default()
    };
    tweak(&mut cfg);
    Router::start(&cfg, &specs).expect("router start")
}

// ---------------------------------------------------------------------------
// Wire clients (same shape as serve_smoke's)
// ---------------------------------------------------------------------------

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: impl std::net::ToSocketAddrs) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(20))).expect("read timeout");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        Client { stream, reader }
    }

    fn call(&mut self, req: Json) -> Json {
        let mut line = req.to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes()).expect("write");
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("read");
        Json::parse(&reply).expect("parse reply")
    }

    fn infer(&mut self, rows: Vec<Vec<i64>>, deadline_ms: u64) -> Json {
        let rows = Json::arr(
            rows.into_iter()
                .map(|r| Json::Arr(r.into_iter().map(|v| Json::num(v as f64)).collect())),
        );
        self.call(Json::obj(vec![
            ("op", Json::str("infer")),
            ("model", Json::str("smoke")),
            ("rows", rows),
            ("deadline_ms", Json::num(deadline_ms as f64)),
        ]))
    }
}

struct BinClient {
    stream: TcpStream,
    frame: Vec<u8>,
    scratch: Vec<u8>,
}

impl BinClient {
    fn connect(addr: impl std::net::ToSocketAddrs) -> BinClient {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(20))).expect("read timeout");
        BinClient { stream, frame: Vec::new(), scratch: Vec::new() }
    }

    fn infer(&mut self, hash: u64, rows: usize, codes: &[i64], deadline_ms: u64) -> wire::Reply {
        wire::encode_infer_request(&mut self.frame, hash, rows, 12, deadline_ms, codes);
        self.stream.write_all(&self.frame).expect("write frame");
        wire::read_reply(&mut self.stream, &mut self.scratch).expect("reply frame")
    }

    fn simple(&mut self, op: u8) -> wire::Reply {
        wire::encode_simple_request(&mut self.frame, op);
        self.stream.write_all(&self.frame).expect("write frame");
        wire::read_reply(&mut self.stream, &mut self.scratch).expect("reply frame")
    }
}

fn ok(reply: &Json) -> bool {
    reply.get("ok").and_then(|v| v.as_bool()).unwrap_or(false)
}

fn code(reply: &Json) -> String {
    reply.opt("code").and_then(|c| c.as_str().ok()).unwrap_or("").to_string()
}

/// Resolve the model hash through whatever speaks the JSON protocol —
/// through the router this relays like any data-plane op.
fn model_hash(c: &mut Client) -> u64 {
    let info = c.call(Json::obj(vec![
        ("op", Json::str("model_info")),
        ("model", Json::str("smoke")),
    ]));
    assert!(ok(&info), "{info:?}");
    info.get("hash").unwrap().as_str().unwrap().parse().expect("hash parses")
}

fn replica_states(stats: &Json) -> Vec<(String, String)> {
    stats
        .get("replicas")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| {
            (
                r.get("addr").unwrap().as_str().unwrap().to_string(),
                r.get("state").unwrap().as_str().unwrap().to_string(),
            )
        })
        .collect()
}

/// Poll the router's stats until `pred` holds (the prober needs a beat to
/// observe state changes).
fn wait_for(ctl: &mut Client, what: &str, mut pred: impl FnMut(&Json) -> bool) -> Json {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = ctl.call(Json::obj(vec![("op", Json::str("stats"))]));
        if pred(&stats) {
            return stats;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}: {stats:?}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn counter(stats: &Json, name: &str) -> u64 {
    stats.get(name).unwrap().as_u64().unwrap()
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

/// The router is transparent: both protocols relay bit-identically to a
/// direct replica hit, the router answers its own pings, refuses
/// un-routable binary admin ops typed, and a client `shutdown` op unblocks
/// `Router::join` — the exact blocking pattern `a2q route` relies on.
#[test]
fn router_relays_bit_identically_and_shuts_down_on_op() {
    let replica = ServeChild::spawn(None);
    let router = router_over(&[&replica.addr], |_| {});

    // Direct reference replies, both protocols.
    let mut dj = Client::connect(replica.addr.as_str());
    let hash = model_hash(&mut dj);
    let dref = dj.infer(vec![vec![1; 12], vec![3; 12]], 1000);
    assert!(ok(&dref), "{dref:?}");
    let mut db = BinClient::connect(replica.addr.as_str());
    let codes: Vec<i64> = (0..2 * 12).map(|i| (i % 4) as i64).collect();
    let bref = db.infer(hash, 2, &codes, 1000);
    assert!(matches!(bref, wire::Reply::InferOk { .. }), "{bref:?}");

    // JSON through the router: ping answered locally, data plane relayed.
    let mut c = Client::connect(router.addr());
    let pong = c.call(Json::obj(vec![("op", Json::str("ping"))]));
    assert!(ok(&pong), "{pong:?}");
    assert_eq!(pong.get("role").unwrap().as_str().unwrap(), "router");
    assert_eq!(model_hash(&mut c), hash, "model_info relays through the router");
    let via = c.infer(vec![vec![1; 12], vec![3; 12]], 1000);
    assert_eq!(dref.to_string(), via.to_string(), "JSON relay is bit-identical");

    // Binary through the router.
    let mut b = BinClient::connect(router.addr());
    assert_eq!(b.simple(wire::OP_PING), wire::Reply::Pong { draining: false, in_flight: 0 });
    assert_eq!(b.infer(hash, 2, &codes, 1000), bref, "binary relay is bit-identical");
    match b.simple(wire::OP_DRAIN) {
        wire::Reply::Err { tag, .. } => {
            assert_eq!(ServeError::code_for_tag(tag), Some("bad_request"));
        }
        other => panic!("binary drain at the router must be refused typed, got {other:?}"),
    }

    // Stats carry router counters and one row per replica.
    let stats = c.call(Json::obj(vec![("op", Json::str("stats"))]));
    assert_eq!(stats.get("role").unwrap().as_str().unwrap(), "router");
    assert!(counter(&stats, "forwarded") >= 3, "{stats:?}");
    assert_eq!(replica_states(&stats), vec![(replica.addr.clone(), "up".to_string())]);

    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    router.join();
}

/// Kill -9 one of two replicas mid-stream: every subsequent request keeps
/// succeeding bit-identically; the breaker takes the dead replica out of
/// rotation and the survivor carries the traffic.
#[test]
fn replica_kill_is_invisible_to_clients() {
    let mut victim = ServeChild::spawn(None);
    let survivor = ServeChild::spawn(None);
    let router = router_over(&[&victim.addr, &survivor.addr], |_| {});
    let mut ctl = Client::connect(router.addr());
    let hash = model_hash(&mut ctl);

    let mut b = BinClient::connect(router.addr());
    let codes = vec![1i64; 2 * 12];
    let reference = b.infer(hash, 2, &codes, 2000);
    assert!(matches!(reference, wire::Reply::InferOk { .. }), "{reference:?}");

    victim.kill();
    for i in 0..12 {
        let got = b.infer(hash, 2, &codes, 2000);
        assert_eq!(reference, got, "request {i} after the kill must be bit-identical");
    }
    // The breaker opens on the dead replica; the survivor stays up.
    let stats = wait_for(&mut ctl, "victim breaker to open", |s| {
        replica_states(s).iter().any(|(a, st)| a == &victim.addr && st == "down")
    });
    assert!(
        replica_states(&stats).iter().any(|(a, st)| a == &survivor.addr && st == "up"),
        "{stats:?}"
    );

    assert!(ok(&ctl.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    router.join();
}

/// Deterministic torn-reply handling: a replica that cuts the connection
/// halfway through every second reply (`conn_drop:2`) costs the router a
/// retry per tear — the client sees only complete, identical replies.
#[test]
fn torn_replies_are_retried_never_relayed() {
    let replica = ServeChild::spawn(Some("conn_drop:2"));
    let router = router_over(&[&replica.addr], |cfg| cfg.breaker_threshold = 10);
    let mut ctl = Client::connect(router.addr());
    let hash = model_hash(&mut ctl);

    let mut b = BinClient::connect(router.addr());
    let codes = vec![1i64; 12];
    let reference = b.infer(hash, 1, &codes, 2000);
    assert!(matches!(reference, wire::Reply::InferOk { .. }), "{reference:?}");
    for i in 0..7 {
        let got = b.infer(hash, 1, &codes, 2000);
        assert_eq!(reference, got, "request {i} must survive the torn reply");
    }
    // Every second backend reply is torn, so the retry counter must have
    // moved — and every tear was absorbed, never relayed.
    let stats = ctl.call(Json::obj(vec![("op", Json::str("stats"))]));
    assert!(counter(&stats, "retries") >= 3, "{stats:?}");

    assert!(ok(&ctl.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    router.join();
}

/// The addressed drain control op is zero-loss: the drained replica
/// refuses new work typed while the router routes around it; `resume`
/// re-admits it within a probe interval.
#[test]
fn drain_via_router_routes_around_and_resume_readmits() {
    let a = ServeChild::spawn(None);
    let b_replica = ServeChild::spawn(None);
    let router = router_over(&[&a.addr, &b_replica.addr], |_| {});
    let mut ctl = Client::connect(router.addr());
    let hash = model_hash(&mut ctl);

    let drained = ctl.call(Json::obj(vec![
        ("op", Json::str("drain")),
        ("backend", Json::str(a.addr.as_str())),
    ]));
    assert!(ok(&drained), "{drained:?}");
    assert_eq!(drained.get("state").unwrap().as_str().unwrap(), "draining");

    // The drained replica refuses direct hits typed...
    let mut direct = BinClient::connect(a.addr.as_str());
    let codes = vec![1i64; 12];
    match direct.infer(hash, 1, &codes, 1000) {
        wire::Reply::Err { tag, .. } => {
            assert_eq!(ServeError::code_for_tag(tag), Some("draining"));
        }
        other => panic!("drained replica must refuse typed, got {other:?}"),
    }
    // ...while clients of the router never notice.
    let mut b = BinClient::connect(router.addr());
    let reference = b.infer(hash, 1, &codes, 2000);
    assert!(matches!(reference, wire::Reply::InferOk { .. }), "{reference:?}");
    for _ in 0..6 {
        assert_eq!(b.infer(hash, 1, &codes, 2000), reference);
    }

    // Admin ops validate their target.
    let bogus = ctl.call(Json::obj(vec![
        ("op", Json::str("drain")),
        ("backend", Json::str("127.0.0.1:1")),
    ]));
    assert_eq!(code(&bogus), "bad_request", "{bogus:?}");

    // Resume: the replica re-enters rotation via the probe loop.
    let resumed = ctl.call(Json::obj(vec![
        ("op", Json::str("resume")),
        ("backend", Json::str(a.addr.as_str())),
    ]));
    assert!(ok(&resumed), "{resumed:?}");
    wait_for(&mut ctl, "drained replica to re-admit", |s| {
        replica_states(s).iter().any(|(addr, st)| addr == &a.addr && st == "up")
    });
    assert_eq!(b.infer(hash, 1, &codes, 2000), reference, "re-admitted replica is bit-identical");

    assert!(ok(&ctl.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    router.join();
}

/// Tail-latency hedging: with one replica injected 150ms slow, a 40ms
/// hedge duplicates the infer onto the fast replica and the duplicate's
/// reply wins — bit-identical, of course.
#[test]
fn hedging_wins_over_a_slow_replica() {
    let slow = ServeChild::spawn(Some("delay_ms:150"));
    let fast = ServeChild::spawn(None);
    let router = router_over(&[&slow.addr, &fast.addr], |cfg| cfg.hedge_ms = 40);
    let mut ctl = Client::connect(router.addr());
    let hash = model_hash(&mut ctl);

    let mut b = BinClient::connect(router.addr());
    let codes = vec![1i64; 12];
    let reference = b.infer(hash, 1, &codes, 2000);
    assert!(matches!(reference, wire::Reply::InferOk { .. }), "{reference:?}");
    for _ in 0..7 {
        assert_eq!(b.infer(hash, 1, &codes, 2000), reference);
    }
    // Round-robin started roughly half the requests on the slow replica;
    // each of those must have hedged, and the fast duplicate must have won
    // at least once.
    let stats = ctl.call(Json::obj(vec![("op", Json::str("stats"))]));
    assert!(counter(&stats, "hedges") >= 1, "{stats:?}");
    assert!(counter(&stats, "hedge_wins") >= 1, "{stats:?}");

    assert!(ok(&ctl.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    router.join();
}

/// Whole-pool death: the router survives every replica dying, sheds typed
/// `no_backend` on both protocols, and automatically re-admits + respawns
/// a spawned replica — clients never see a transport error throughout.
#[test]
fn dead_pool_sheds_typed_and_respawn_readmits() {
    std::env::set_var("A2Q_SERVE_BIN", env!("CARGO_BIN_EXE_a2q"));
    let cfg = RouterConfig {
        addr: "127.0.0.1:0".to_string(),
        probe_interval_ms: 20,
        respawn: true,
        ..RouterConfig::default()
    };
    let specs = [BackendSpec::Spawn { models: SPEC.to_string(), workers: 1 }];
    let router = Router::start(&cfg, &specs).expect("router start");
    let mut ctl = Client::connect(router.addr());
    let hash = model_hash(&mut ctl);

    let mut b = BinClient::connect(router.addr());
    let codes = vec![1i64; 12];
    let reference = b.infer(hash, 1, &codes, 2000);
    assert!(matches!(reference, wire::Reply::InferOk { .. }), "{reference:?}");

    // Kill the only replica from outside the router (graceful shutdown so
    // its ephemeral port frees immediately; the router only sees a backend
    // that stopped answering).
    let stats = ctl.call(Json::obj(vec![("op", Json::str("stats"))]));
    let (old_addr, _) = replica_states(&stats)[0].clone();
    let mut killer = BinClient::connect(old_addr.as_str());
    assert_eq!(killer.simple(wire::OP_SHUTDOWN), wire::Reply::Ok { op: wire::OP_SHUTDOWN });
    drop(killer);

    // Until the respawn lands every request fails TYPED on the same
    // still-open client connection; afterwards requests succeed again,
    // bit-identically. No transport errors at any point.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut recovered = false;
    while Instant::now() < deadline {
        match b.infer(hash, 1, &codes, 2000) {
            got @ wire::Reply::InferOk { .. } => {
                assert_eq!(reference, got, "respawned replica must serve identically");
                recovered = true;
                break;
            }
            wire::Reply::Err { tag, .. } => {
                let c = ServeError::code_for_tag(tag).unwrap_or("unknown_tag");
                assert!(
                    matches!(c, "no_backend" | "shutting_down" | "draining" | "overloaded"),
                    "only typed shed codes may surface while the pool is down, got {c}"
                );
                std::thread::sleep(Duration::from_millis(30));
            }
            other => panic!("expected InferOk or a typed error, got {other:?}"),
        }
    }
    assert!(recovered, "the router must respawn and re-admit its spawned replica");
    let stats = ctl.call(Json::obj(vec![("op", Json::str("stats"))]));
    assert!(counter(&stats, "respawns") >= 1, "{stats:?}");

    router.shutdown();
    router.join(); // also kills the respawned child
}

/// The centerpiece: open-loop load through the router while one replica is
/// killed -9, a second is drained, and a third panics a worker batch.
/// Every request succeeds or sheds typed; the transport-error classes the
/// loadgen distinguishes stay exactly zero.
#[test]
fn open_loop_load_survives_kill_drain_and_panic() {
    let mut victim = ServeChild::spawn(None);
    let drained = ServeChild::spawn(None);
    let panicky = ServeChild::spawn(Some("panic_batch:3"));
    let router = router_over(&[&victim.addr, &drained.addr, &panicky.addr], |cfg| {
        cfg.retry = RetryPolicy { max_attempts: 4, base_ms: 1, cap_ms: 20 };
    });
    let raddr = router.addr();

    let load = std::thread::spawn(move || {
        a2q::serve::run_loadgen(&LoadgenConfig {
            addr: raddr.to_string(),
            model: "smoke".to_string(),
            rps: 250.0,
            duration_ms: 1800,
            connections: 4,
            rows_per_req: 2,
            deadline_ms: 1000,
            connect_timeout_ms: 2000,
            seed: 11,
            wire: WireFormat::Binary,
        })
    });

    // Mid-load choreography: kill -9 one replica, drain another through
    // the router's control plane.
    std::thread::sleep(Duration::from_millis(400));
    victim.kill();
    std::thread::sleep(Duration::from_millis(300));
    let mut ctl = Client::connect(router.addr());
    let ack = ctl.call(Json::obj(vec![
        ("op", Json::str("drain")),
        ("backend", Json::str(drained.addr.as_str())),
    ]));
    assert!(ok(&ack), "{ack:?}");

    let report = load.join().expect("loadgen thread").expect("loadgen run");
    assert!(report.ok > 0, "requests must still be served: {report:?}");
    assert_eq!(report.conn_refused, 0, "no transport errors through the router: {report:?}");
    assert_eq!(report.conn_reset, 0, "no transport errors through the router: {report:?}");
    assert_eq!(report.timeout, 0, "no transport errors through the router: {report:?}");
    assert_eq!(report.errors_other, 0, "no untyped failures through the router: {report:?}");
    assert_eq!(report.overflow_events, 0, "failover must never cost correctness");

    // The kill forced failover retries; the storm is over and the pool
    // still serves — resume the drained replica and hit it via the router.
    let stats = ctl.call(Json::obj(vec![("op", Json::str("stats"))]));
    assert!(counter(&stats, "retries") >= 1, "{stats:?}");
    let ack = ctl.call(Json::obj(vec![
        ("op", Json::str("resume")),
        ("backend", Json::str(drained.addr.as_str())),
    ]));
    assert!(ok(&ack), "{ack:?}");
    wait_for(&mut ctl, "drained replica to re-admit", |s| {
        replica_states(s).iter().any(|(addr, st)| addr == &drained.addr && st == "up")
    });
    let hash = model_hash(&mut ctl);
    let mut b = BinClient::connect(router.addr());
    let codes = vec![1i64; 12];
    let via = b.infer(hash, 1, &codes, 2000);
    assert!(matches!(via, wire::Reply::InferOk { .. }), "{via:?}");
    let mut direct = BinClient::connect(drained.addr.as_str());
    assert_eq!(direct.infer(hash, 1, &codes, 2000), via, "post-storm replies stay bit-identical");

    assert!(ok(&ctl.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    router.join();
}