//! Smoke-scale network-forward perf run wired into `cargo test`: exercises
//! the multi-layer bench pipeline (per-mode scalar composition vs the fused
//! `NetworkPlan`, journal write, EXPERIMENTS.md PERF-NET-SMOKE refresh) at a
//! size that finishes in well under a second. Lives in its own test binary
//! so its journal read-modify-write cannot race `tests/bench_smoke.rs`
//! (cargo runs test binaries sequentially).
//!
//! Timing numbers here come from the *debug* profile and land in the
//! `accsim_smoke/netfwd_*` journal entries; the authoritative release
//! numbers come from `cargo bench --bench network_forward`.

use std::time::Instant;

use a2q::accsim::{network_forward_multi, AccMode};
use a2q::model::network_forward_ref;
use a2q::perf::{self, BenchRecord};
use a2q::testutil::psweep_network;

#[test]
fn network_smoke_records_journal() {
    let quick = std::env::var("A2Q_BENCH_QUICK").map(|v| v != "0").unwrap_or(true);
    let (widths, batch, reps): (Vec<usize>, usize, usize) =
        if quick { (vec![64, 32, 16, 4], 8, 2) } else { (vec![256, 128, 64, 10], 32, 4) };
    let (net, x) = psweep_network(&widths, batch, 7);
    let modes: Vec<AccMode> = std::iter::once(AccMode::Wide)
        .chain((8..=32).map(|p| AccMode::Wrap { p_bits: p }))
        .collect();
    let macs = (reps * modes.len() * batch * net.macs_per_row()) as u64;

    // Correctness at smoke scale: the fused network pass is bit-identical
    // to the per-mode scalar composition on the exact bench configuration
    // (the property test covers this broadly; this guards the fixture).
    let fused_once = network_forward_multi(&net, &x, &modes);
    for (mi, mode) in modes.iter().enumerate() {
        let r = network_forward_ref(&net, &x, *mode);
        assert_eq!(fused_once[mi].out.data(), r.out.data(), "{mode:?}");
        for (li, (a, b)) in fused_once[mi].layer_stats.iter().zip(&r.layer_stats).enumerate() {
            assert_eq!(a.overflow_events, b.overflow_events, "{mode:?} layer {li}");
        }
    }

    let t0 = Instant::now();
    let mut sink = 0u64;
    for _ in 0..reps {
        for mode in &modes {
            let r = network_forward_ref(&net, &x, *mode);
            sink ^= r.layer_stats.iter().map(|s| s.overflow_events).sum::<u64>();
        }
    }
    let t_ref = t0.elapsed();

    let t1 = Instant::now();
    for _ in 0..reps {
        sink ^= network_forward_multi(&net, &x, &modes)
            .iter()
            .flat_map(|r| r.layer_stats.iter())
            .map(|s| s.overflow_events)
            .sum::<u64>();
    }
    let t_fused = t1.elapsed();
    std::hint::black_box(sink);

    let speedup = t_ref.as_secs_f64() / t_fused.as_secs_f64().max(1e-12);
    let per_iter = |t: std::time::Duration| t.as_nanos() as f64 / reps as f64;
    let mac_rate = |t: std::time::Duration| macs as f64 / t.as_secs_f64().max(1e-12);
    println!(
        "smoke network forward ({} modes, layers {widths:?}, batch {batch}, debug profile): \
         fused {speedup:.1}x over per-mode scalar composition",
        modes.len()
    );

    let baseline = BenchRecord {
        name: "accsim_smoke/netfwd_scalar_composed".into(),
        ns_per_iter: per_iter(t_ref),
        mac_per_s: Some(mac_rate(t_ref)),
        sparsity: None,
    };
    let fused = BenchRecord {
        name: "accsim_smoke/netfwd_fused_network".into(),
        ns_per_iter: per_iter(t_fused),
        mac_per_s: Some(mac_rate(t_fused)),
        sparsity: None,
    };
    match perf::record_benches(&[baseline.clone(), fused.clone()]) {
        Ok(path) => {
            let journal = perf::parse_journal(&std::fs::read_to_string(path).unwrap()).unwrap();
            assert!(journal.iter().any(|r| r.name == "accsim_smoke/netfwd_fused_network"));
        }
        Err(e) => eprintln!("perf journal not writable here ({e}); measurements printed only"),
    }

    let block = perf::render_psweep_block(
        &format!("`cargo test` (debug profile{})", if quick { ", quick" } else { "" }),
        &baseline,
        &fused,
        &format!("{} modes, layers {widths:?}, batch {batch}", modes.len()),
    );
    if let Err(e) = perf::update_experiments_net_smoke_block(&block) {
        eprintln!("EXPERIMENTS.md not writable here ({e}); net smoke block not updated");
    }
}
