//! Randomized property tests over the coordinator-side invariants (offline
//! replacement for proptest, driven by the deterministic in-tree RNG).
//!
//! Each property runs a few hundred random cases; failures print the seed so
//! the case is exactly reproducible.

use a2q::accsim::{
    dot_accumulate, dot_accumulate_multi, qlinear_forward_ref, AccMode, IntMatrix, LayerPlan,
    NetworkPlan,
};
use a2q::accsim::dot::wrap_to;
use a2q::model::{network_forward_ref, NetSpec, QNetwork, SynthQuant};
use a2q::quant::QTensor;
use a2q::tensor::Tensor;
use a2q::config::SweepConfig;
use a2q::json::Json;
use a2q::pareto::{dominates, frontier, Point};
use a2q::quant::a2q::{a2q_quantize_row, l1_cap, row_satisfies_cap};
use a2q::quant::bounds::{data_type_bound, weight_bound_exact, DotShape};
use a2q::rng::Rng;

const CASES: usize = 300;

/// THE theorem (paper Eq. 5 + Eq. 15): if every channel's integer l1 norm
/// satisfies the cap, then NO input — and no intermediate partial sum — can
/// overflow a P-bit register, under any MAC ordering.
#[test]
fn prop_cap_implies_no_overflow_any_input_any_order() {
    let mut rng = Rng::new(0xA2);
    for case in 0..CASES {
        let k = 1 + rng.below(300);
        let n_bits = 1 + rng.below(8) as u32;
        let p_bits = 8 + rng.below(16) as u32;
        let signed = rng.below(2) == 1;
        // random A2Q-quantized weights (the quantizer enforces the cap)
        let v: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 3.0).collect();
        let d = -6.0 + rng.uniform() as f32 * 3.0;
        let t = rng.uniform() as f32 * 16.0;
        let (w_int, _) = a2q_quantize_row(&v, d, t, 8, n_bits, p_bits, signed);
        assert!(row_satisfies_cap(&w_int, p_bits, n_bits, signed), "case {case}");
        let w: Vec<i64> = w_int.iter().map(|x| *x as i64).collect();

        // adversarial worst-case input: sign-matched max-magnitude values
        let xmax: i64 = 1 << (n_bits - if signed { 1 } else { 0 });
        let mut x: Vec<i64> = w
            .iter()
            .map(|wi| if *wi >= 0 { xmax } else if signed { -xmax } else { xmax })
            .collect();
        // random order
        let mut idx: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut idx);
        let xp: Vec<i64> = idx.iter().map(|&i| x[i]).collect();
        let wp: Vec<i64> = idx.iter().map(|&i| w[i]).collect();
        let r = dot_accumulate(&xp, &wp, AccMode::Wrap { p_bits });
        assert_eq!(r.overflows, 0, "case {case}: k={k} n={n_bits} p={p_bits}");
        // and the wrap result equals the wide result
        let wide = dot_accumulate(&xp, &wp, AccMode::Wide);
        assert_eq!(r.value, wide.value, "case {case}");
        // negate some inputs (still within range): still safe
        for xi in x.iter_mut() {
            if rng.below(2) == 0 {
                *xi = if signed { -*xi } else { 0 };
            }
        }
        let r2 = dot_accumulate(&x, &w, AccMode::Wrap { p_bits });
        assert_eq!(r2.overflows, 0, "case {case} (perturbed inputs)");
    }
}

/// Wraparound and saturation agree with the wide register exactly when no
/// overflow occurs, and wrap_to is an involution-compatible 2^P modulus.
#[test]
fn prop_modes_agree_without_overflow() {
    let mut rng = Rng::new(0xB3);
    for case in 0..CASES {
        let k = 1 + rng.below(200);
        let p_bits = 10 + rng.below(20) as u32;
        // keep sum(|x||w|) well inside the register
        let cap = ((1i64 << (p_bits - 1)) - 1) / k as i64;
        let lim = (cap as f64).sqrt().max(1.0) as i64;
        let x: Vec<i64> = (0..k).map(|_| rng.below((2 * lim + 1) as usize) as i64 - lim).collect();
        let w: Vec<i64> = (0..k).map(|_| rng.below((2 * lim + 1) as usize) as i64 - lim).collect();
        let wide = dot_accumulate(&x, &w, AccMode::Wide);
        for mode in [
            AccMode::Wrap { p_bits },
            AccMode::Saturate { p_bits },
            AccMode::SaturateFinal { p_bits },
        ] {
            let r = dot_accumulate(&x, &w, mode);
            assert_eq!(r.value, wide.value, "case {case} {mode:?}");
            assert_eq!(r.overflows, 0, "case {case} {mode:?}");
        }
    }
}

/// The fused multi-P kernel engine is bit-identical, per mode, to running
/// the scalar per-P reference once per mode — outputs, wide outputs and
/// every statistics field — across random shapes and bit widths, for all
/// four `AccMode`s, including channels the `Σ|w| * max|x|` bound gates onto
/// the no-simulation fast path, at several worker counts.
#[test]
fn prop_fused_multi_p_bit_exact() {
    let mut rng = Rng::new(0x5EED);
    for case in 0..60 {
        let batch = 1 + rng.below(6);
        let c_out = 1 + rng.below(5);
        let k = 1 + rng.below(64);
        let n_bits = 1 + rng.below(6) as u32;
        let signed = rng.below(2) == 1;
        let x_scale = 0.25 + rng.uniform() as f32;

        // Weight rows in three magnitude classes so the sweep hits all-zero
        // channels, bound-safe channels AND register-overflow channels.
        let mut wdata = Vec::with_capacity(c_out * k);
        for c in 0..c_out {
            let amp: i64 = match (c + case) % 3 {
                0 => 0,
                1 => 2,
                _ => 3000,
            };
            for _ in 0..k {
                wdata.push(if amp == 0 {
                    0.0
                } else {
                    (rng.below((2 * amp + 1) as usize) as i64 - amp) as f32
                });
            }
        }
        let w = QTensor::from_export(
            &Tensor::new(vec![c_out, k], wdata),
            &Tensor::new(vec![c_out, 1], (0..c_out).map(|_| 0.1 + rng.uniform() as f32).collect()),
            &Tensor::from_vec((0..c_out).map(|_| rng.normal() as f32).collect()),
        );

        let xmax: i64 = 1 << (n_bits - u32::from(signed));
        let xdata: Vec<i64> = (0..batch * k)
            .map(|_| {
                let lo = if signed { -xmax } else { 0 };
                lo + rng.below((xmax - lo + 1) as usize) as i64
            })
            .collect();
        let x = IntMatrix::from_flat(batch, k, xdata);

        // Random mode multiset over all four register models, mixed widths
        // (duplicates and unsorted orders allowed).
        let n_modes = 1 + rng.below(12);
        let modes: Vec<AccMode> = (0..n_modes)
            .map(|_| {
                let p_bits = 2 + rng.below(47) as u32;
                match rng.below(4) {
                    0 => AccMode::Wide,
                    1 => AccMode::Wrap { p_bits },
                    2 => AccMode::Saturate { p_bits },
                    _ => AccMode::SaturateFinal { p_bits },
                }
            })
            .collect();

        let refs: Vec<_> =
            modes.iter().map(|m| qlinear_forward_ref(&x, x_scale, &w, *m)).collect();
        let plan = LayerPlan::new(&w, &modes);
        for threads in [1usize, 2, 7] {
            let multi = plan.execute_threads(&x, x_scale, threads);
            assert_eq!(multi.len(), modes.len(), "case {case}");
            for (mi, mode) in modes.iter().enumerate() {
                let (a, b) = (&multi[mi], &refs[mi]);
                assert_eq!(a.out.data(), b.out.data(), "case {case} {mode:?} t={threads}");
                assert_eq!(a.out_wide.data(), b.out_wide.data(), "case {case} {mode:?}");
                assert_eq!(a.stats.dots, b.stats.dots, "case {case} {mode:?}");
                assert_eq!(a.stats.macs, b.stats.macs, "case {case} {mode:?}");
                assert_eq!(
                    a.stats.overflow_events, b.stats.overflow_events,
                    "case {case} {mode:?} t={threads}"
                );
                assert_eq!(
                    a.stats.dots_overflowed, b.stats.dots_overflowed,
                    "case {case} {mode:?}"
                );
                assert_eq!(a.stats.abs_err_sum, b.stats.abs_err_sum, "case {case} {mode:?}");
                assert_eq!(a.stats.outputs, b.stats.outputs, "case {case} {mode:?}");
            }
        }

        // Dot-level fusion agrees with the scalar walk too.
        let row0 = x.row(0).to_vec();
        let fused = dot_accumulate_multi(&row0, w.row(0), &modes);
        for (mi, mode) in modes.iter().enumerate() {
            assert_eq!(
                fused[mi],
                dot_accumulate(&row0, w.row(0), *mode),
                "case {case} dot {mode:?}"
            );
        }
    }
}

/// The fused multi-layer [`NetworkPlan`] is bit-identical, per mode, to
/// composing the scalar per-layer reference with explicit requantization
/// ([`network_forward_ref`]) — final outputs, final wide outputs and every
/// per-layer statistics field — across random depths/widths/bit-widths,
/// all four `AccMode`s, A2Q-constrained (bound-gated) and unconstrained
/// (actually-overflowing, group-splitting) weights, and thread counts.
#[test]
fn prop_network_fused_bit_exact() {
    let mut rng = Rng::new(0x9E7);
    for case in 0..30 {
        let depth = 2 + rng.below(3);
        let mut widths = vec![1 + rng.below(20)];
        for _ in 0..depth {
            widths.push(1 + rng.below(12));
        }
        let spec = NetSpec {
            widths,
            m_bits: 3 + rng.below(5) as u32,
            n_bits: 1 + rng.below(5) as u32,
            p_bits: 6 + rng.below(12) as u32,
            x_signed: rng.below(2) == 1,
            quant: if case % 2 == 0 { SynthQuant::A2q } else { SynthQuant::Affine },
        };
        let mut net = QNetwork::synthesize(&spec, 0x5EED ^ case as u64).unwrap();

        let batch = 1 + rng.below(6);
        let dim = spec.widths[0];
        let sample = Tensor::new(
            vec![batch, dim],
            (0..batch * dim)
                .map(|_| {
                    let v = rng.normal() as f32;
                    if spec.x_signed { v } else { v.abs() }
                })
                .collect(),
        );
        net.calibrate(&sample);
        let x = net.layers[0].in_quant.quantize(&sample);

        // Random mode multiset over all four register models, mixed widths.
        let n_modes = 1 + rng.below(8);
        let modes: Vec<AccMode> = (0..n_modes)
            .map(|_| {
                let p_bits = 2 + rng.below(40) as u32;
                match rng.below(4) {
                    0 => AccMode::Wide,
                    1 => AccMode::Wrap { p_bits },
                    2 => AccMode::Saturate { p_bits },
                    _ => AccMode::SaturateFinal { p_bits },
                }
            })
            .collect();

        let refs: Vec<_> = modes.iter().map(|m| network_forward_ref(&net, &x, *m)).collect();
        let plan = NetworkPlan::new(&net, &modes);
        for threads in [1usize, 2, 7] {
            let multi = plan.execute_threads(&x, threads);
            assert_eq!(multi.len(), modes.len(), "case {case}");
            for (mi, mode) in modes.iter().enumerate() {
                let (a, b) = (&multi[mi], &refs[mi]);
                assert_eq!(a.out.data(), b.out.data(), "case {case} {mode:?} t={threads}");
                assert_eq!(a.out_wide.data(), b.out_wide.data(), "case {case} {mode:?}");
                assert_eq!(a.layer_stats.len(), b.layer_stats.len(), "case {case}");
                for (li, (sa, sb)) in a.layer_stats.iter().zip(&b.layer_stats).enumerate() {
                    let ctx = format!("case {case} {mode:?} layer {li} t={threads}");
                    assert_eq!(sa.dots, sb.dots, "{ctx}");
                    assert_eq!(sa.macs, sb.macs, "{ctx}");
                    assert_eq!(sa.overflow_events, sb.overflow_events, "{ctx}");
                    assert_eq!(sa.dots_overflowed, sb.dots_overflowed, "{ctx}");
                    assert_eq!(sa.abs_err_sum, sb.abs_err_sum, "{ctx}");
                    assert_eq!(sa.outputs, sb.outputs, "{ctx}");
                }
            }
        }

        // Constrained nets are the theorem at network scale: no overflow at
        // or above the synthesis target, at any depth.
        if spec.quant.constrained() {
            let r = network_forward_ref(&net, &x, AccMode::Wrap { p_bits: spec.p_bits });
            for (li, s) in r.layer_stats.iter().enumerate() {
                assert_eq!(s.overflow_events, 0, "case {case} layer {li} overflowed at target");
            }
        }
    }
}

/// A register-model multiset built to stress the safety partition: every
/// family, extreme widths (Wrap can go down to 1 bit, where *no* nonzero
/// channel is ever fully safe), and duplicates that must keep their slots.
fn adversarial_modes() -> Vec<AccMode> {
    vec![
        AccMode::Wide,
        AccMode::Wrap { p_bits: 4 },
        AccMode::Wrap { p_bits: 4 }, // duplicate keeps its own slot
        AccMode::Saturate { p_bits: 5 },
        AccMode::SaturateFinal { p_bits: 6 },
        AccMode::Wrap { p_bits: 63 },
        AccMode::Saturate { p_bits: 2 },
        AccMode::Wrap { p_bits: 1 },
        AccMode::Wide, // duplicate Wide
    ]
}

/// Pin the partitioned layer engine bit-exact against the scalar reference
/// — outputs, wide outputs and every [`OverflowStats`] counter — for one
/// fixture, at thread counts {1, 2, 7}.
fn assert_layer_bit_exact(w: &QTensor, x: &IntMatrix, x_scale: f32, modes: &[AccMode], ctx: &str) {
    let refs: Vec<_> = modes.iter().map(|m| qlinear_forward_ref(x, x_scale, w, *m)).collect();
    let plan = LayerPlan::new(w, modes);
    for threads in [1usize, 2, 7] {
        let multi = plan.execute_threads(x, x_scale, threads);
        assert_eq!(multi.len(), modes.len(), "{ctx}");
        for (mi, mode) in modes.iter().enumerate() {
            let (a, b) = (&multi[mi], &refs[mi]);
            let tag = format!("{ctx} {mode:?} t={threads}");
            assert_eq!(a.out.shape(), b.out.shape(), "{tag}");
            assert_eq!(a.out.data(), b.out.data(), "{tag}");
            assert_eq!(a.out_wide.data(), b.out_wide.data(), "{tag}");
            assert_eq!(a.stats.dots, b.stats.dots, "{tag}");
            assert_eq!(a.stats.macs, b.stats.macs, "{tag}");
            assert_eq!(a.stats.overflow_events, b.stats.overflow_events, "{tag}");
            assert_eq!(a.stats.dots_overflowed, b.stats.dots_overflowed, "{tag}");
            assert_eq!(a.stats.abs_err_sum, b.stats.abs_err_sum, "{tag}");
            assert_eq!(a.stats.outputs, b.stats.outputs, "{tag}");
        }
    }
}

/// Degenerate and adversarial shapes for the safety-partitioned layer
/// kernel: k = 0, empty batch, single-row batch, all-zero rows (xmax = 0
/// gates everything onto the GEMM), all-channels-safe and no-channels-safe
/// layers, mixed spans that split mid-set, i32-packed and pack-rejected
/// code magnitudes — each pinned bit-exact against the scalar reference.
#[test]
fn prop_partitioned_layer_degenerate_shapes() {
    let layer = |c_out: usize, k: usize, codes: Vec<i64>| QTensor {
        codes,
        scales: (0..c_out).map(|c| 0.25 + c as f32 * 0.5).collect(),
        bias: (0..c_out).map(|c| c as f32 - 0.75).collect(),
        c_out,
        k,
    };

    // k = 0: every channel is trivially safe; outputs are pure bias.
    assert_layer_bit_exact(
        &layer(3, 0, vec![]),
        &IntMatrix::zeros(4, 0),
        0.5,
        &adversarial_modes(),
        "k=0",
    );

    let mixed = layer(
        4,
        3,
        vec![
            0, 0, 0, // all-zero channel: safe at any width
            1, -1, 1, // tiny channel: safe for every width >= 3
            30, -20, 25, // mid channel
            3000, 3000, -3000, // huge channel: unsafe at narrow widths
        ],
    );
    // Empty batch.
    assert_layer_bit_exact(&mixed, &IntMatrix::zeros(0, 3), 1.0, &adversarial_modes(), "batch=0");
    // Single-row batch.
    assert_layer_bit_exact(
        &mixed,
        &IntMatrix::from_rows(&[vec![7, -3, 2]]),
        1.0,
        &adversarial_modes(),
        "batch=1",
    );
    // All-zero rows: xmax = 0, the whole grid is provably safe.
    assert_layer_bit_exact(&mixed, &IntMatrix::zeros(5, 3), 1.0, &adversarial_modes(), "x=0");
    // Mixed rows: zero, small and max-magnitude rows give different per-row
    // safe prefixes, so the block-common GEMM span and the per-row safe
    // remainder both run.
    assert_layer_bit_exact(
        &mixed,
        &IntMatrix::from_rows(&[
            vec![0, 0, 0],
            vec![1, 1, -1],
            vec![127, -127, 127],
            vec![0, 1, 0],
            vec![-128, 127, -128],
        ]),
        0.125,
        &adversarial_modes(),
        "mixed-rows",
    );

    // All channels safe: tiny norms under generous widths only.
    let wide_modes = [
        AccMode::Wide,
        AccMode::Wrap { p_bits: 40 },
        AccMode::Saturate { p_bits: 40 },
        AccMode::SaturateFinal { p_bits: 8 },
    ];
    assert_layer_bit_exact(
        &layer(2, 4, vec![1, -1, 2, 1, 0, 1, -1, 0]),
        &IntMatrix::from_rows(&[vec![3, 1, -2, 0], vec![1, 1, 1, 1]]),
        1.0,
        &wide_modes,
        "all-safe",
    );
    // No channel safe: huge norms under a 4-bit register.
    assert_layer_bit_exact(
        &layer(2, 4, vec![3000, -3000, 3000, 3000, 2000, 2000, -2000, 2000]),
        &IntMatrix::from_rows(&[vec![255, 255, 255, 255], vec![1, -1, 1, -1]]),
        1.0,
        &[AccMode::Wrap { p_bits: 4 }, AccMode::Saturate { p_bits: 4 }],
        "no-safe",
    );
    // Codes beyond i16 force the i32 panels; beyond i32 the pack is
    // rejected and the engine falls back to unpacked wide dots.
    assert_layer_bit_exact(
        &layer(2, 2, vec![100_000, -70_000, 1, 2]),
        &IntMatrix::from_rows(&[vec![5, -9], vec![0, 3]]),
        1.0,
        &adversarial_modes(),
        "i32-packed",
    );
    assert_layer_bit_exact(
        &layer(2, 2, vec![3_000_000_000, 1, -2, 4]),
        &IntMatrix::from_rows(&[vec![2, -3], vec![1, 0]]),
        1.0,
        &adversarial_modes(),
        "pack-rejected",
    );
}

/// Pin the partitioned network engine bit-exact against the composed
/// scalar reference for one fixture, at thread counts {1, 2, 7}.
fn assert_network_bit_exact(net: &QNetwork, x: &IntMatrix, modes: &[AccMode], ctx: &str) {
    let refs: Vec<_> = modes.iter().map(|m| network_forward_ref(net, x, *m)).collect();
    let plan = NetworkPlan::new(net, modes);
    for threads in [1usize, 2, 7] {
        let multi = plan.execute_threads(x, threads);
        assert_eq!(multi.len(), modes.len(), "{ctx}");
        for (mi, mode) in modes.iter().enumerate() {
            let (a, b) = (&multi[mi], &refs[mi]);
            let tag = format!("{ctx} {mode:?} t={threads}");
            assert_eq!(a.out.shape(), b.out.shape(), "{tag}");
            assert_eq!(a.out.data(), b.out.data(), "{tag}");
            assert_eq!(a.out_wide.data(), b.out_wide.data(), "{tag}");
            assert_eq!(a.layer_stats.len(), b.layer_stats.len(), "{tag}");
            for (li, (sa, sb)) in a.layer_stats.iter().zip(&b.layer_stats).enumerate() {
                assert_eq!(sa.dots, sb.dots, "{tag} layer {li}");
                assert_eq!(sa.macs, sb.macs, "{tag} layer {li}");
                assert_eq!(sa.overflow_events, sb.overflow_events, "{tag} layer {li}");
                assert_eq!(sa.dots_overflowed, sb.dots_overflowed, "{tag} layer {li}");
                assert_eq!(sa.abs_err_sum, sb.abs_err_sum, "{tag} layer {li}");
                assert_eq!(sa.outputs, sb.outputs, "{tag} layer {li}");
            }
        }
    }
}

/// Degenerate and adversarial shapes for the partitioned *network* engine:
/// a k = 0 first layer, empty and single-row batches, all-zero inputs, and
/// duplicate modes — each pinned bit-exact (final outputs, wide outputs,
/// every per-layer stats counter) against the composed scalar reference.
#[test]
fn prop_partitioned_network_degenerate_shapes() {
    use a2q::model::{ActQuant, QLayer};

    let qlayer = |name: &str, c_out: usize, k: usize, codes: Vec<i64>, signed: bool| QLayer {
        name: name.into(),
        weights: QTensor {
            codes,
            scales: vec![0.5; c_out],
            bias: (0..c_out).map(|c| 0.1 * c as f32).collect(),
            c_out,
            k,
        },
        in_quant: ActQuant::new(3, signed, 0.75),
        m_bits: 4,
        p_bits: 8,
    };

    // Layer 0 has k = 0 (pure-bias layer feeding a real layer).
    let net = QNetwork::new(
        "degenerate",
        vec![
            qlayer("k0", 3, 0, vec![], false),
            qlayer("dense", 2, 3, vec![9, -2, 4, 3000, -3000, 3000], true),
        ],
    )
    .unwrap();
    let modes = adversarial_modes();
    assert_network_bit_exact(&net, &IntMatrix::zeros(0, 0), &modes, "net batch=0");
    assert_network_bit_exact(&net, &IntMatrix::zeros(1, 0), &modes, "net batch=1 k=0");
    assert_network_bit_exact(&net, &IntMatrix::zeros(5, 0), &modes, "net k=0");

    // A calibrated synthesized net on zero and mixed inputs (zero rows gate
    // whole layers onto the GEMM span; nonzero rows split mode groups).
    let spec = NetSpec {
        widths: vec![6, 5, 4, 3],
        m_bits: 5,
        n_bits: 4,
        p_bits: 8,
        x_signed: false,
        quant: SynthQuant::Affine,
    };
    let mut net = QNetwork::synthesize(&spec, 0xD6).unwrap();
    let sample = Tensor::new(vec![4, 6], (0..24).map(|i| (i % 5) as f32 * 0.21).collect());
    net.calibrate(&sample);
    assert_network_bit_exact(&net, &IntMatrix::zeros(3, 6), &modes, "net x=0");
    let x = net.layers[0].in_quant.quantize(&sample);
    assert_network_bit_exact(&net, &x, &modes, "net mixed");
    let one = IntMatrix::from_flat(1, 6, x.rows_slice(0, 1).to_vec());
    assert_network_bit_exact(&net, &one, &modes, "net batch=1");
}

#[test]
fn prop_wrap_to_is_modular() {
    let mut rng = Rng::new(0xC4);
    for _ in 0..CASES {
        let p = 2 + rng.below(40) as u32;
        let v = rng.next_u64() as i64 >> rng.below(30);
        let m = 1i128 << p;
        let r = wrap_to(v, p) as i128;
        assert!((-(m / 2)..m / 2).contains(&r));
        assert_eq!((r - v as i128).rem_euclid(m), 0, "p={p} v={v}");
    }
}

/// The weight-norm bound is never looser than the data-type bound, and the
/// bound is monotone in the l1 norm.
#[test]
fn prop_weight_bound_tighter_and_monotone() {
    let mut rng = Rng::new(0xD5);
    for case in 0..CASES {
        let k = 1 + rng.below(4096);
        let m_bits = 2 + rng.below(7) as u32;
        let n_bits = 1 + rng.below(8) as u32;
        let signed = rng.below(2) == 1;
        let worst = k as f64 * (2f64.powi(m_bits as i32 - 1));
        let l1 = rng.uniform() * worst;
        let dt = data_type_bound(DotShape { k, m_bits, n_bits, x_signed: signed });
        let wb = weight_bound_exact(l1, n_bits, signed);
        assert!(wb <= dt as f64 + 1.0, "case {case}: wb {wb} vs dt {dt}");
        let wb2 = weight_bound_exact(l1 * 0.5, n_bits, signed);
        assert!(wb2 <= wb, "case {case}: monotonicity");
    }
}

/// l1_cap round trip: a norm exactly at the cap needs exactly P bits by the
/// weight bound (up to ceiling).
#[test]
fn prop_cap_and_bound_are_inverse() {
    for p in 8..28u32 {
        for n in 1..8u32 {
            for signed in [false, true] {
                let cap = l1_cap(p, n, signed);
                let need = a2q::quant::bounds::weight_bound(cap, n, signed);
                assert!(need <= p, "P={p} N={n} signed={signed}: need {need}");
                // just above the cap must need more than P bits
                let need2 = a2q::quant::bounds::weight_bound(cap * 1.01 + 1.0, n, signed);
                assert!(need2 > p, "P={p} N={n}: need2 {need2}");
            }
        }
    }
}

/// Pareto frontier properties: every frontier point is undominated, every
/// non-frontier point is dominated by some frontier point.
#[test]
fn prop_frontier_correctness() {
    let mut rng = Rng::new(0xE6);
    for case in 0..100 {
        let n = 2 + rng.below(80);
        let pts: Vec<Point<usize>> = (0..n)
            .map(|i| Point {
                cost: (rng.below(20) as f64) + 1.0,
                perf: rng.uniform(),
                tag: i,
            })
            .collect();
        let front = frontier(&pts);
        assert!(!front.is_empty());
        for fp in &front {
            assert!(
                !pts.iter().any(|p| dominates(p, fp)),
                "case {case}: frontier point dominated"
            );
        }
        for p in &pts {
            let on_front = front.iter().any(|fp| fp.cost == p.cost && fp.perf == p.perf);
            if !on_front {
                assert!(
                    front
                        .iter()
                        .any(|fp| dominates(fp, p) || (fp.cost == p.cost && fp.perf >= p.perf)),
                    "case {case}: non-frontier point not covered"
                );
            }
        }
    }
}

/// JSON fuzz: serialize(parse(serialize(v))) is a fixed point for random
/// nested values.
#[test]
fn prop_json_round_trip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => Json::Str(format!("s{}-\"q\"\n", rng.below(1000))),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect::<Vec<_>>()
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(0xF7);
    for case in 0..CASES {
        let v = random_json(&mut rng, 3);
        let s1 = v.to_string();
        let v2 = Json::parse(&s1).unwrap_or_else(|e| panic!("case {case}: {e}\n{s1}"));
        assert_eq!(v, v2, "case {case}");
        assert_eq!(s1, v2.to_string(), "case {case}");
    }
}

/// Sweep expansion invariants: every expanded config validates, P never
/// exceeds the data-type bound anchor, and expansion is deterministic.
#[test]
fn prop_sweep_expansion() {
    let mut rng = Rng::new(0x17);
    for case in 0..100 {
        let k = 8 + rng.below(4000);
        let mut sweep = SweepConfig::default_grid(vec!["m".into()], 1 + rng.below(100) as u64);
        sweep.mn_values = vec![5 + rng.below(4) as u32];
        sweep.p_offsets = (0..1 + rng.below(10)).map(|_| rng.below(12) as u32).collect();
        let runs = sweep.expand_for_model("m", k);
        assert!(!runs.is_empty(), "case {case}");
        for r in &runs {
            r.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        }
        assert_eq!(runs, sweep.expand_for_model("m", k), "case {case}: determinism");
        // qat appears exactly once per mn value
        let qats = runs.iter().filter(|r| r.alg == "qat").count();
        assert_eq!(qats, sweep.mn_values.len(), "case {case}");
    }
}

/// The `WeightQuantizer` A2Q impl is THE paper quantizer: bit-exact against
/// `a2q_quantize_row` across random shapes, parameters and bit widths
/// (codes AND scales), so the native training backend's forward is pinned
/// to the audited reference.
#[test]
fn prop_weight_quantizer_a2q_bit_exact() {
    use a2q::quant::quantizer::{A2qQuantizer, WeightQuantizer};

    let mut rng = Rng::new(0xB17);
    for case in 0..CASES {
        let k = 1 + rng.below(500);
        let v: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 3.0).collect();
        let d = -10.0 + rng.uniform() as f32 * 10.0;
        let t = -4.0 + rng.uniform() as f32 * 20.0;
        let m = 2 + rng.below(7) as u32;
        let n = 1 + rng.below(8) as u32;
        let p = 4 + rng.below(28) as u32;
        let signed = rng.below(2) == 1;
        let (wq, sq) = A2qQuantizer.quantize_row(&v, d, t, m, n, p, signed);
        let (wr, sr) = a2q_quantize_row(&v, d, t, m, n, p, signed);
        assert_eq!(sq.to_bits(), sr.to_bits(), "case {case}: scale drift");
        assert_eq!(wq.len(), wr.len(), "case {case}");
        for (i, (a, b)) in wq.iter().zip(&wr).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} code {i}: {a} vs {b}");
        }
    }
}

/// A2Q+ invariants on the same random family: every zero-centered row still
/// passes the Eq. 15 audit at its (N, P), and never spends more integer l1
/// norm than the plain-A2Q row quantized from the same inputs.
#[test]
fn prop_a2q_plus_capped_and_norm_monotone() {
    use a2q::quant::quantizer::{A2qPlusQuantizer, A2qQuantizer, WeightQuantizer};

    let mut rng = Rng::new(0xB18);
    for case in 0..CASES {
        let k = 1 + rng.below(500);
        let v: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 2.0).collect();
        let d = -8.0 + rng.uniform() as f32 * 6.0;
        let t = -2.0 + rng.uniform() as f32 * 16.0;
        let m = 2 + rng.below(7) as u32;
        let n = 1 + rng.below(8) as u32;
        let p = 6 + rng.below(20) as u32;
        let signed = rng.below(2) == 1;
        let (wp, _) = A2qPlusQuantizer.quantize_row(&v, d, t, m, n, p, signed);
        assert!(
            row_satisfies_cap(&wp, p, n, signed),
            "case {case}: A2Q+ row violates Eq. 15 at N={n} P={p}"
        );
        let (wb, _) = A2qQuantizer.quantize_row(&v, d, t, m, n, p, signed);
        let l1p: i64 = wp.iter().map(|x| x.abs() as i64).sum();
        let l1b: i64 = wb.iter().map(|x| x.abs() as i64).sum();
        assert!(l1p <= l1b, "case {case}: A2Q+ l1 {l1p} exceeds plain-A2Q l1 {l1b}");
        // codes stay inside the M-bit signed range
        let hi = (1i64 << (m - 1)) - 1;
        assert!(
            wp.iter().all(|w| (*w as i64) >= -hi - 1 && (*w as i64) <= hi),
            "case {case}: code outside {m}-bit range"
        );
    }
}

/// The blocked+threaded native train path tracks the scalar reference
/// within tight f32 tolerance, and is *bit-identical* across thread counts
/// {1, 2, 7} — forward/input-grad rows never reassociate a dot product,
/// and the weight-grad reduction sums fixed-size blocks in block order.
#[test]
fn prop_native_blocked_train_matches_scalar_and_is_thread_invariant() {
    use a2q::datasets::{self, Split};
    use a2q::runtime::{ComputePath, NativeBackend, TrainBackend};

    for (model, bits, alg) in [
        ("mlp3", (4u32, 4u32, 14u32), "a2q"),
        ("mlp3_adam", (4u32, 4u32, 14u32), "a2q_plus"),
        ("mlp", (8u32, 1u32, 16u32), "qat"),
    ] {
        let run = |be: NativeBackend| {
            let manifest = be.manifest(model).unwrap();
            let ds = datasets::by_name("synth_mnist", 256, 64, 0).unwrap();
            let idx: Vec<usize> = (0..manifest.batch_size).collect();
            let b = ds.gather(Split::Train, &idx);
            let mut state = be.init(&manifest, 9.0).unwrap();
            let mut losses = Vec::new();
            for _ in 0..4 {
                losses.push(
                    be.train_step(&manifest, alg, &mut state, &b.x, &b.y, bits, 0.05).unwrap(),
                );
            }
            (losses, state)
        };

        let (loss_ref, state_ref) =
            run(NativeBackend::new("artifacts").with_compute(ComputePath::Scalar));
        let (loss_t1, state_t1) = run(NativeBackend::new("artifacts").with_threads(1));

        // scalar vs blocked: different summation order, same training run.
        // Tolerances are loose enough to absorb a quantization-grid code
        // flip from an ulp-level pre-activation difference, tight enough
        // to catch any transposed/garbled GEMM immediately.
        for ((i, a), b) in loss_ref.iter().enumerate().zip(&loss_t1) {
            assert!(
                (a - b).abs() <= 0.05 * (1.0 + a.abs()),
                "{model}: loss[{i}] scalar {a} vs blocked {b}"
            );
        }
        for (i, (a, b)) in state_ref.leaves.iter().zip(&state_t1.leaves).enumerate() {
            let max_ref = a.data().iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let tol = 0.05 * (1.0 + max_ref);
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!(
                    (x - y).abs() <= tol,
                    "{model}: leaf {i} scalar {x} vs blocked {y} (tol {tol})"
                );
            }
        }

        // blocked path: bit-identical at every thread count
        for threads in [2usize, 7] {
            let (loss_t, state_t) = run(NativeBackend::new("artifacts").with_threads(threads));
            assert_eq!(loss_t1, loss_t, "{model}: losses differ at {threads} threads");
            for (i, (a, b)) in state_t1.leaves.iter().zip(&state_t.leaves).enumerate() {
                assert_eq!(
                    a.data(),
                    b.data(),
                    "{model}: leaf {i} differs between 1 and {threads} threads"
                );
            }
        }
    }
}

/// Every forced GEMM kernel path (scalar blocked loops, SIMD microkernels,
/// sparse packed panels) is bit-exact against the per-mode scalar MAC
/// reference through the full accsim plan — outputs, wide outputs and every
/// statistic — across random shapes (k = 0 and empty batches included),
/// weight densities from all-zero to dense, magnitudes that reject the pack
/// entirely (codes beyond i32), and worker counts {1, 2, 7}. The plan's
/// `KernelChoice` must also report the forced path, the layer's measured
/// sparsity, and whether the pack fell back.
#[test]
fn prop_forced_kernel_paths_bit_exact_through_the_plan() {
    use a2q::accsim::KernelPath;
    let mut rng = Rng::new(0xD15C);
    for case in 0..60 {
        let c_out = 1 + rng.below(18);
        let k = rng.below(70); // 0 = degenerate no-MAC layer
        let batch = rng.below(6); // 0 = empty batch
        let keep = [0.0, 0.3, 1.0][rng.below(3)];
        // every 5th case uses codes beyond i32 so PackedWeights::pack
        // refuses and the plan must fall back to the fused scalar walk
        let amp: i64 = if case % 5 == 0 { (i32::MAX as i64) * 4 } else { 120 };
        let codes: Vec<i64> = (0..c_out * k)
            .map(|_| {
                if rng.uniform() < keep {
                    let mag = 1 + rng.below(amp as usize) as i64;
                    if rng.below(2) == 0 { mag } else { -mag }
                } else {
                    0
                }
            })
            .collect();
        let w = QTensor {
            codes,
            scales: (0..c_out).map(|_| 0.05 + rng.uniform() as f32).collect(),
            bias: (0..c_out).map(|_| rng.normal() as f32).collect(),
            c_out,
            k,
        };
        let x = IntMatrix::from_flat(
            batch,
            k,
            (0..batch * k).map(|_| rng.below(256) as i64).collect(),
        );
        let n_modes = 1 + rng.below(8);
        let modes: Vec<AccMode> = (0..n_modes)
            .map(|_| {
                let p_bits = 8 + rng.below(40) as u32;
                match rng.below(3) {
                    0 => AccMode::Wide,
                    1 => AccMode::Wrap { p_bits },
                    _ => AccMode::Saturate { p_bits },
                }
            })
            .collect();

        let refs: Vec<_> = modes.iter().map(|m| qlinear_forward_ref(&x, 0.5, &w, *m)).collect();
        let packable = w.codes.iter().all(|c| i32::try_from(*c).is_ok());
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::SparseSimd] {
            let plan = LayerPlan::new_with_path(&w, &modes, Some(path));
            let choice = plan.kernel_choice();
            assert_eq!(choice.sparsity, w.sparsity(), "case {case} {path:?}");
            assert_eq!(choice.pack_fallback, !packable, "case {case} {path:?}");
            if !choice.pack_fallback {
                assert_eq!(choice.path, path, "case {case}");
            }
            for threads in [1usize, 2, 7] {
                let multi = plan.execute_threads(&x, 0.5, threads);
                for (mi, mode) in modes.iter().enumerate() {
                    let (a, b) = (&multi[mi], &refs[mi]);
                    let ctx = format!("case {case} {path:?} {mode:?} t={threads}");
                    assert_eq!(a.out.data(), b.out.data(), "{ctx}");
                    assert_eq!(a.out_wide.data(), b.out_wide.data(), "{ctx}");
                    assert_eq!(a.stats.overflow_events, b.stats.overflow_events, "{ctx}");
                    assert_eq!(a.stats.abs_err_sum, b.stats.abs_err_sum, "{ctx}");
                }
            }
        }
    }
}

/// On A2Q-quantized layers (the regime the sparse panels are built for: the
/// Eq. 15 l1 budget zeroes most weights at tight P), every forced kernel
/// path reproduces the scalar-forced plan bitwise, and a tighter budget
/// yields a sparser layer than a looser one.
#[test]
fn prop_forced_kernel_paths_agree_on_a2q_constrained_layers() {
    use a2q::accsim::KernelPath;
    use a2q::testutil::psweep_constrained_layer;
    let mut rng = Rng::new(0xCAF);
    for (case, p_bits) in [14u32, 16, 20, 28].iter().enumerate() {
        let (c_out, k) = (8 + case * 4, 48 + case * 24);
        let w = psweep_constrained_layer(c_out, k, *p_bits, 8, case as u64);
        let x = IntMatrix::from_flat(
            5,
            k,
            (0..5 * k).map(|_| rng.below(256) as i64).collect(),
        );
        let modes: Vec<AccMode> =
            (*p_bits..=*p_bits + 8).map(|p| AccMode::Wrap { p_bits: p }).collect();
        let base = LayerPlan::new_with_path(&w, &modes, Some(KernelPath::Scalar))
            .execute_threads(&x, 1.0, 1);
        for path in [KernelPath::Simd, KernelPath::SparseSimd] {
            let plan = LayerPlan::new_with_path(&w, &modes, Some(path));
            assert!(!plan.kernel_choice().pack_fallback, "case {case}");
            for threads in [1usize, 3] {
                let got = plan.execute_threads(&x, 1.0, threads);
                for (mi, mode) in modes.iter().enumerate() {
                    assert_eq!(
                        got[mi].out.data(),
                        base[mi].out.data(),
                        "case {case} {path:?} {mode:?} t={threads}"
                    );
                    assert_eq!(
                        got[mi].stats.overflow_events, base[mi].stats.overflow_events,
                        "case {case} {path:?} {mode:?}"
                    );
                }
            }
        }
    }
    // tighter accumulator budget => more zeros for the sparse path to skip
    let tight = psweep_constrained_layer(16, 96, 14, 8, 3).sparsity();
    let loose = psweep_constrained_layer(16, 96, 28, 8, 3).sparsity();
    assert!(tight > loose, "sparsity should grow as P tightens: {tight} vs {loose}");
}

/// The NNUE-style incremental stream session is bit-identical to the batch
/// recompute on its current input — outputs AND every [`OverflowStats`]
/// counter, per layer — across delta densities (empty tick, sparse, heavy,
/// whole-row), refresh thresholds (always-refresh, default, never-refresh),
/// thread counts and forced kernel paths. This is the determinism contract
/// of `accsim::stream`: the Eq. 15 safety partition is re-derived from the
/// updated inputs on every forward, so overflow accounting can never drift
/// from what a from-scratch `NetworkPlan::execute` would report.
#[test]
fn prop_stream_session_matches_full_recompute() {
    use a2q::accsim::{KernelPath, StreamSession};
    use a2q::testutil::{apply_deltas, psweep_network, stream_delta_tick};
    let mut rng = Rng::new(0x57AE);
    let widths = vec![24usize, 16, 8];
    let batch = 6;
    let n_bits = 4u32;
    let modes =
        [AccMode::Wide, AccMode::Wrap { p_bits: 16 }, AccMode::Saturate { p_bits: 12 }];
    let paths =
        [None, Some(KernelPath::Scalar), Some(KernelPath::Simd), Some(KernelPath::SparseSimd)];
    for (case, path) in paths.iter().enumerate() {
        let (net, x0) = psweep_network(&widths, batch, 11 + case as u64);
        let plan = NetworkPlan::new_with_path(&net, &modes, *path);
        for threshold in [0.0, 0.5, 1.1] {
            let mut session =
                StreamSession::new(&plan, x0.clone()).with_refresh_threshold(threshold);
            let mut mirror = x0.clone();
            // Escalating densities per tick: empty, ~4%, ~30%, whole-row
            // (the last crosses the refresh cap at thresholds <= 1.0).
            for per_row in [0usize, 1, 7, widths[0]] {
                let tick = stream_delta_tick(session.x(), per_row, n_bits, &mut rng);
                session.apply(&tick).unwrap();
                apply_deltas(&mut mirror, &tick);
                let ctx = format!("{path:?} thr={threshold} per_row={per_row}");
                assert_eq!(session.x(), &mirror, "{ctx}");
                for threads in [1usize, 2, 7] {
                    let got = session.forward_threads(threads);
                    let want = plan.execute_threads(&mirror, threads);
                    assert_eq!(got.len(), want.len(), "{ctx}");
                    for (mi, (g, b)) in got.iter().zip(&want).enumerate() {
                        let tag = format!("{ctx} t={threads} mode {mi}");
                        assert_eq!(g.out.data(), b.out.data(), "{tag}");
                        assert_eq!(g.out_wide.data(), b.out_wide.data(), "{tag}");
                        for (li, (gs, bs)) in
                            g.layer_stats.iter().zip(&b.layer_stats).enumerate()
                        {
                            let ltag = format!("{tag} layer {li}");
                            assert_eq!(gs.dots, bs.dots, "{ltag}");
                            assert_eq!(gs.macs, bs.macs, "{ltag}");
                            assert_eq!(gs.overflow_events, bs.overflow_events, "{ltag}");
                            assert_eq!(gs.dots_overflowed, bs.dots_overflowed, "{ltag}");
                            assert_eq!(gs.abs_err_sum, bs.abs_err_sum, "{ltag}");
                            assert_eq!(gs.outputs, bs.outputs, "{ltag}");
                        }
                    }
                }
            }
        }
    }
}
