//! Smoke-scale perf run wired into `cargo test`: exercises the full bench
//! pipeline (per-P scalar baseline vs the fused multi-P engine, journal
//! write, EXPERIMENTS.md block refresh) at a size that finishes in well
//! under a second.
//!
//! Respects `A2Q_BENCH_QUICK`: quick by default under the test harness; set
//! `A2Q_BENCH_QUICK=0` for the bench-scale shape. Timing numbers recorded
//! here come from the *debug* profile and land in the separate
//! `accsim_smoke/*` journal entries and PERF-SMOKE block — the authoritative
//! release numbers come from `cargo bench --bench runtime_hotpath`.

use std::time::Instant;

use a2q::accsim::{
    qlinear_forward_multi, qlinear_forward_ref, AccMode, IntMatrix, KernelPath, LayerPlan,
};
use a2q::perf::{self, BenchRecord};
use a2q::rng::Rng;
use a2q::testutil::{psweep_constrained_layer, psweep_layer};

#[test]
fn bench_smoke_psweep_records_journal() {
    let quick = std::env::var("A2Q_BENCH_QUICK").map(|v| v != "0").unwrap_or(true);
    let (batch, c_out, k, reps) = if quick { (8, 16, 256, 2) } else { (64, 64, 1024, 5) };

    let layer = psweep_layer(c_out, k, 7);
    let mut rng = Rng::new(8);
    let x = IntMatrix::from_flat(batch, k, (0..batch * k).map(|_| rng.below(256) as i64).collect());
    let modes: Vec<AccMode> = (8..=32).map(|p| AccMode::Wrap { p_bits: p }).collect();
    let macs = (reps * modes.len() * batch * c_out * k) as u64;

    // Correctness at smoke scale (the property test covers this broadly;
    // here it guards the exact bench configuration).
    let fused_once = qlinear_forward_multi(&x, 1.0, &layer, &modes);
    for (mi, mode) in modes.iter().enumerate() {
        let r = qlinear_forward_ref(&x, 1.0, &layer, *mode);
        assert_eq!(fused_once[mi].out.data(), r.out.data(), "{mode:?}");
        assert_eq!(fused_once[mi].stats.overflow_events, r.stats.overflow_events, "{mode:?}");
    }

    let t0 = Instant::now();
    let mut sink = 0u64;
    for _ in 0..reps {
        for mode in &modes {
            sink ^= qlinear_forward_ref(&x, 1.0, &layer, *mode).stats.overflow_events;
        }
    }
    let t_ref = t0.elapsed();

    let t1 = Instant::now();
    for _ in 0..reps {
        sink ^= qlinear_forward_multi(&x, 1.0, &layer, &modes)
            .iter()
            .map(|s| s.stats.overflow_events)
            .sum::<u64>();
    }
    let t_fused = t1.elapsed();

    // The headline A2Q scenario at smoke scale: a constrained layer swept
    // at/above its target width, where the Eq. 15 cap proves every channel
    // safe and the partitioned engine rides the packed GEMM end to end.
    let clayer = psweep_constrained_layer(c_out, k, 16, 8, 7);
    let cmodes: Vec<AccMode> = (16..=40).map(|p| AccMode::Wrap { p_bits: p }).collect();
    let cmacs = (reps * cmodes.len() * batch * c_out * k) as u64;
    let c_once = qlinear_forward_multi(&x, 1.0, &clayer, &cmodes);
    for (mi, mode) in cmodes.iter().enumerate() {
        let r = qlinear_forward_ref(&x, 1.0, &clayer, *mode);
        assert_eq!(c_once[mi].out.data(), r.out.data(), "{mode:?}");
        assert_eq!(c_once[mi].stats.overflow_events, 0, "{mode:?} overflowed at/above target");
    }
    let t2 = Instant::now();
    for _ in 0..reps {
        for mode in &cmodes {
            sink ^= qlinear_forward_ref(&x, 1.0, &clayer, *mode).stats.overflow_events;
        }
    }
    let t_cref = t2.elapsed();
    let t3 = Instant::now();
    for _ in 0..reps {
        sink ^= qlinear_forward_multi(&x, 1.0, &clayer, &cmodes)
            .iter()
            .map(|s| s.stats.overflow_events)
            .sum::<u64>();
    }
    let t_cgemm = t3.elapsed();
    std::hint::black_box(sink);

    let speedup = t_ref.as_secs_f64() / t_fused.as_secs_f64().max(1e-12);
    let per_iter = |t: std::time::Duration| t.as_nanos() as f64 / reps as f64;
    let mac_rate = |t: std::time::Duration| macs as f64 / t.as_secs_f64().max(1e-12);
    println!(
        "smoke psweep ({} widths, {batch}x{c_out}x{k}, debug profile): fused {speedup:.1}x over per-P scalar",
        modes.len()
    );

    // Journal under smoke-specific names so release bench entries survive.
    // Recording degrades gracefully (like the bench harness) so `cargo test`
    // still passes from a read-only or relocated checkout.
    let baseline = BenchRecord {
        name: "accsim_smoke/psweep25_scalar_baseline".into(),
        ns_per_iter: per_iter(t_ref),
        mac_per_s: Some(mac_rate(t_ref)),
        sparsity: None,
    };
    let fused = BenchRecord {
        name: "accsim_smoke/psweep25_fused_engine".into(),
        ns_per_iter: per_iter(t_fused),
        mac_per_s: Some(mac_rate(t_fused)),
        sparsity: None,
    };
    let cmac_rate = |t: std::time::Duration| cmacs as f64 / t.as_secs_f64().max(1e-12);
    let cbaseline = BenchRecord {
        name: "accsim_smoke/psweep25_constrained_scalar".into(),
        ns_per_iter: per_iter(t_cref),
        mac_per_s: Some(cmac_rate(t_cref)),
        sparsity: None,
    };
    let cgemm = BenchRecord {
        name: "accsim_smoke/psweep25_constrained_gemm".into(),
        ns_per_iter: per_iter(t_cgemm),
        mac_per_s: Some(cmac_rate(t_cgemm)),
        sparsity: None,
    };
    println!(
        "smoke constrained psweep ({} widths at/above target, {batch}x{c_out}x{k}, debug \
         profile): safe-span GEMM {:.1}x over per-P scalar",
        cmodes.len(),
        t_cref.as_secs_f64() / t_cgemm.as_secs_f64().max(1e-12)
    );
    match perf::record_benches(&[baseline.clone(), fused.clone(), cbaseline, cgemm]) {
        Ok(path) => {
            let journal = perf::parse_journal(&std::fs::read_to_string(path).unwrap()).unwrap();
            assert!(journal.iter().any(|r| r.name == "accsim_smoke/psweep25_fused_engine"));
            assert!(journal.iter().any(|r| r.name == "accsim_smoke/psweep25_constrained_gemm"));
        }
        Err(e) => eprintln!("perf journal not writable here ({e}); measurements printed only"),
    }

    let block = perf::render_psweep_block(
        &format!("`cargo test` (debug profile{})", if quick { ", quick" } else { "" }),
        &baseline,
        &fused,
        &format!("{} widths, batch {batch} x c_out {c_out} x k {k}", modes.len()),
    );
    if let Err(e) = perf::update_experiments_smoke_block(&block) {
        eprintln!("EXPERIMENTS.md not writable here ({e}); smoke block not updated");
    }
}

/// Smoke-scale kernel-dispatch comparison on a tightly-constrained (= very
/// sparse) layer: every forced path must reproduce the scalar reference
/// bit-for-bit, serial and threaded, and the three timings land in the
/// journal with the measured weight sparsity attached.
#[test]
fn bench_smoke_kernel_paths_on_tight_layer() {
    let quick = std::env::var("A2Q_BENCH_QUICK").map(|v| v != "0").unwrap_or(true);
    let (batch, c_out, k, reps) = if quick { (8, 16, 256, 2) } else { (64, 64, 1024, 5) };

    // P=14 with 8-bit inputs caps each row's l1 norm at 8191/255 ≈ 32
    // nonzero full-scale codes — the Eq. 15 budget forces most of the k
    // weights to zero, which is exactly the regime the sparse panels target.
    let layer = psweep_constrained_layer(c_out, k, 14, 8, 7);
    let sparsity = layer.sparsity();
    assert!(sparsity >= 0.70, "tight fixture must be mostly zeros, got {sparsity:.3}");

    let mut rng = Rng::new(21);
    let x = IntMatrix::from_flat(batch, k, (0..batch * k).map(|_| rng.below(256) as i64).collect());
    let modes: Vec<AccMode> = (14..=20).map(|p| AccMode::Wrap { p_bits: p }).collect();
    let macs = (reps * modes.len() * batch * c_out * k) as u64;

    let refs: Vec<_> = modes.iter().map(|m| qlinear_forward_ref(&x, 1.0, &layer, *m)).collect();
    let mut records = Vec::new();
    for (label, path) in [
        ("scalar", KernelPath::Scalar),
        ("simd", KernelPath::Simd),
        ("sparse", KernelPath::SparseSimd),
    ] {
        let plan = LayerPlan::new_with_path(&layer, &modes, Some(path));
        assert_eq!(plan.kernel_choice().path, path, "{label}");
        for threads in [1, 2] {
            let got = plan.execute_threads(&x, 1.0, threads);
            for ((g, r), mode) in got.iter().zip(&refs).zip(&modes) {
                assert_eq!(g.out.data(), r.out.data(), "{label} t{threads} {mode:?}");
                assert_eq!(
                    g.stats.overflow_events, r.stats.overflow_events,
                    "{label} t{threads} {mode:?}"
                );
            }
        }
        let t0 = Instant::now();
        let mut sink = 0u64;
        for _ in 0..reps {
            sink ^= plan
                .execute_threads(&x, 1.0, 1)
                .iter()
                .map(|s| s.stats.overflow_events)
                .sum::<u64>();
        }
        let dt = t0.elapsed();
        std::hint::black_box(sink);
        records.push(BenchRecord {
            name: format!("accsim_smoke/kpath_tight_{label}"),
            ns_per_iter: dt.as_nanos() as f64 / reps as f64,
            mac_per_s: Some(macs as f64 / dt.as_secs_f64().max(1e-12)),
            sparsity: Some(sparsity),
        });
    }
    println!(
        "smoke kpath ({batch}x{c_out}x{k}, sparsity {sparsity:.3}, debug profile): {}",
        records
            .iter()
            .map(|r| format!("{} {:.0}ns", r.name, r.ns_per_iter))
            .collect::<Vec<_>>()
            .join(", ")
    );
    match perf::record_benches(&records) {
        Ok(path) => {
            let journal = perf::parse_journal(&std::fs::read_to_string(path).unwrap()).unwrap();
            for label in ["scalar", "simd", "sparse"] {
                let row = journal
                    .iter()
                    .find(|r| r.name == format!("accsim_smoke/kpath_tight_{label}"))
                    .expect(label);
                assert_eq!(row.sparsity, Some(sparsity), "{label}");
            }
        }
        Err(e) => eprintln!("perf journal not writable here ({e}); measurements printed only"),
    }
}
