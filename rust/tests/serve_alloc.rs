//! The zero-allocation pin for the binary serve hot path.
//!
//! A counting global allocator wraps `System`; the test drives a warmed
//! in-process binary session (session decode → admission → batch worker →
//! reply encode → session write) and asserts the steady-state request→reply
//! loop performs **zero** heap allocations. This file must stay a
//! single-test integration binary: any concurrently running test would
//! allocate on another thread and poison the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::{self, Read};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use a2q::serve::{
    run_binary_session, run_worker, wire, AdmissionQueue, BatchPolicy, BufferPool, FaultPlan,
    ModelSource, PlanCache, ServeStats,
};

/// Counts every allocation-path call (alloc, alloc_zeroed, realloc);
/// deallocations are free to happen (returning pooled storage must not
/// count against the hot path, and `dealloc` never allocates).
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serves the same request frame `total` times from one flat buffer and
/// snapshots the allocation counter the moment the warmup frames have been
/// fully consumed. The session reads with exact-size `read_exact` calls
/// that never straddle a frame boundary, so the snapshot lands exactly
/// between two requests.
struct SnappingReader<'a> {
    data: &'a [u8],
    pos: usize,
    boundary: usize,
    snapshot: &'a AtomicU64, // u64::MAX until taken
}

impl Read for SnappingReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = buf.len().min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        if self.pos >= self.boundary && self.snapshot.load(Ordering::SeqCst) == u64::MAX {
            self.snapshot.store(ALLOC_CALLS.load(Ordering::SeqCst), Ordering::SeqCst);
        }
        Ok(n)
    }
}

const SPEC: &str = "alloc:12x8x3:m4n4p16";
const ROWS: usize = 2;
const COLS: usize = 12;
const WARM: usize = 8;
const MEASURE: usize = 32;

#[test]
fn warmed_binary_infer_round_trip_allocates_nothing() {
    // In-process serving core: cache + queue + pool + one batch worker,
    // exactly the pieces a TCP session would use.
    let cache = Arc::new(PlanCache::new(1, FaultPlan::none()));
    let hash = cache.insert_model("alloc", ModelSource::Synth(SPEC.to_string())).unwrap();
    let queue = Arc::new(AdmissionQueue::new(16));
    let stats = Arc::new(ServeStats::default());
    let pool = Arc::new(BufferPool::new(16));
    let shutdown = AtomicBool::new(false);
    let policy = BatchPolicy { max_rows: 8, window: Duration::ZERO };
    let worker = {
        let (queue, cache, stats) = (queue.clone(), cache.clone(), stats.clone());
        std::thread::spawn(move || run_worker(queue, cache, stats, policy, FaultPlan::none()))
    };

    // One infer frame, repeated: codes well inside the m4n4 input grid.
    let codes: Vec<i64> = (0..ROWS * COLS).map(|i| (i % 4) as i64).collect();
    let mut frame = Vec::new();
    wire::encode_infer_request(&mut frame, hash, ROWS, COLS, 0, &codes);
    let total = WARM + MEASURE;
    let stream: Vec<u8> = frame.repeat(total);

    let snapshot = AtomicU64::new(u64::MAX);
    let reader = SnappingReader {
        data: &stream,
        pos: 0,
        boundary: WARM * frame.len(),
        snapshot: &snapshot,
    };
    // Pre-sized reply sink: Vec<u8> as io::Write only appends, and with
    // enough capacity it never reallocates mid-measurement.
    let mut replies: Vec<u8> = Vec::with_capacity(total * 4096);

    run_binary_session(
        reader,
        &mut replies,
        &queue,
        &cache,
        &stats,
        &shutdown,
        None,
        Duration::from_secs(60),
        0,
        FaultPlan::none(),
        &pool,
    );
    let end = ALLOC_CALLS.load(Ordering::SeqCst);

    queue.close(&stats);
    worker.join().expect("worker exits cleanly");

    // Every request got a successful reply...
    let mut cursor = io::Cursor::new(&replies[..]);
    let mut scratch = Vec::new();
    let mut served = 0usize;
    let mut first: Option<Vec<f32>> = None;
    while (cursor.position() as usize) < replies.len() {
        match wire::read_reply(&mut cursor, &mut scratch).expect("well-formed reply frame") {
            wire::Reply::InferOk { rows, cols, overflow_events, outputs, .. } => {
                assert_eq!((rows, cols), (ROWS, 3));
                assert_eq!(overflow_events, 0, "A2Q net at target P");
                match &first {
                    None => first = Some(outputs),
                    Some(f) => assert_eq!(f, &outputs, "identical requests, identical replies"),
                }
                served += 1;
            }
            other => panic!("expected InferOk, got {other:?}"),
        }
    }
    assert_eq!(served, total, "every frame must be served");

    // ...and the measured window allocated nothing, anywhere: not in the
    // session decode, not in admission, not in the worker's execute or
    // reply encode, not in pool recycling.
    let snap = snapshot.load(Ordering::SeqCst);
    assert_ne!(snap, u64::MAX, "warmup boundary was never reached");
    assert_eq!(
        end - snap,
        0,
        "steady-state binary serve path must not allocate ({MEASURE} requests allocated {} times)",
        end - snap
    );
    assert_eq!(stats.snapshot().completed, total as u64);
}
