//! Smoke-scale streaming-delta perf run wired into `cargo test`: exercises
//! the incremental-session pipeline (delta ticks, refresh policy, journal
//! write) at a size that finishes in well under a second, and pins the
//! session bit-identical to the full recompute on the exact bench
//! configuration. Lives in its own test binary so its journal
//! read-modify-write cannot race the other smoke binaries (cargo runs test
//! binaries sequentially).
//!
//! Timing numbers here come from the *debug* profile and land in the
//! `accsim_smoke/stream_*` journal entries; the authoritative release
//! numbers come from `cargo bench --bench stream_delta`.

use std::time::Instant;

use a2q::accsim::{AccMode, IntMatrix, LayerPlan, LayerStreamSession};
use a2q::perf::{self, BenchRecord};
use a2q::rng::Rng;
use a2q::testutil::{apply_deltas, psweep_constrained_layer, stream_delta_tick};

#[test]
fn stream_smoke_records_journal() {
    let quick = std::env::var("A2Q_BENCH_QUICK").map(|v| v != "0").unwrap_or(true);
    let (c_out, k, batch, reps): (usize, usize, usize, usize) =
        if quick { (16, 32, 8, 2) } else { (64, 128, 32, 4) };
    let ticks = 3usize;
    let (p, n) = (14u32, 8u32);
    let w = psweep_constrained_layer(c_out, k, p, n, 7);
    let sparsity = w.sparsity();
    assert!(sparsity >= 0.70, "stream smoke fixture must be >= 70% sparse, got {sparsity:.3}");
    let modes = [AccMode::Wide, AccMode::Wrap { p_bits: p }];
    let plan = LayerPlan::new(&w, &modes);
    let x_scale = 0.05f32;
    let mut xrng = Rng::new(7 ^ 0x57AE);
    let x0 = IntMatrix::from_flat(
        batch,
        k,
        (0..batch * k).map(|_| xrng.below(1usize << n) as i64).collect(),
    );
    let per_row = ((k as f64) * 0.05).round().max(1.0) as usize;
    let macs = (reps * ticks * batch * c_out * k) as u64;

    // Full-forward mirror over the identically seeded stream.
    let mut frng = Rng::new(0xD5);
    let mut xf = x0.clone();
    let t0 = Instant::now();
    let mut sink = 0u64;
    for _ in 0..reps * ticks {
        let tick = stream_delta_tick(&xf, per_row, n, &mut frng);
        apply_deltas(&mut xf, &tick);
        sink ^= plan.execute_threads(&xf, x_scale, 1)[1].stats.overflow_events;
    }
    let t_full = t0.elapsed();

    let mut srng = Rng::new(0xD5);
    let mut session = LayerStreamSession::new(&plan, x0, x_scale);
    let t1 = Instant::now();
    for _ in 0..reps * ticks {
        let tick = stream_delta_tick(session.x(), per_row, n, &mut srng);
        session.apply(&tick).unwrap();
        sink ^= session.forward_threads(1)[1].stats.overflow_events;
    }
    let t_inc = t1.elapsed();
    std::hint::black_box(sink);

    // Correctness at smoke scale: identical streams must leave identical
    // state — outputs and every overflow counter (the property test covers
    // this broadly; this guards the bench configuration).
    assert_eq!(session.x(), &xf, "incremental input state diverged from the mirror");
    let got = session.forward_threads(1);
    let want = plan.execute_threads(&xf, x_scale, 1);
    for (g, b) in got.iter().zip(&want) {
        assert_eq!(g.out.data(), b.out.data());
        assert_eq!(g.out_wide.data(), b.out_wide.data());
        assert_eq!(g.stats.overflow_events, b.stats.overflow_events);
        assert_eq!(g.stats.dots_overflowed, b.stats.dots_overflowed);
        assert_eq!(g.stats.abs_err_sum, b.stats.abs_err_sum);
        assert_eq!(g.stats.outputs, b.stats.outputs);
    }

    let speedup = t_full.as_secs_f64() / t_inc.as_secs_f64().max(1e-12);
    let per_iter = |t: std::time::Duration| t.as_nanos() as f64 / reps as f64;
    let mac_rate = |t: std::time::Duration| macs as f64 / t.as_secs_f64().max(1e-12);
    println!(
        "smoke stream ({batch} rows x {c_out}x{k}, {per_row} deltas/row, debug profile): \
         incremental {speedup:.1}x over full forward"
    );

    let full = BenchRecord {
        name: "accsim_smoke/stream_full_forward".into(),
        ns_per_iter: per_iter(t_full),
        mac_per_s: Some(mac_rate(t_full)),
        sparsity: Some(sparsity),
    };
    let inc = BenchRecord {
        name: "accsim_smoke/stream_delta_d05".into(),
        ns_per_iter: per_iter(t_inc),
        mac_per_s: Some(mac_rate(t_inc)),
        sparsity: Some(sparsity),
    };
    match perf::record_benches(&[full, inc]) {
        Ok(path) => {
            let journal = perf::parse_journal(&std::fs::read_to_string(path).unwrap()).unwrap();
            assert!(journal.iter().any(|r| r.name == "accsim_smoke/stream_delta_d05"));
        }
        Err(e) => eprintln!("perf journal not writable here ({e}); measurements printed only"),
    }
}
