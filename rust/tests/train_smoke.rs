//! Smoke-scale native-training perf + e2e run wired into `cargo test`:
//! exercises the default build's full train -> export -> audit pipeline on
//! a tiny config (riding the default blocked GEMM + threaded compute path)
//! and journals debug-profile `native_smoke/trainstep_*` rows into
//! BENCH_accsim.json (asserted by CI, mirroring the accsim smoke entries).
//! Lives in its own test binary so its journal read-modify-write cannot
//! race the other smoke tests (cargo runs test binaries sequentially).
//!
//! The authoritative release numbers — including the scalar-reference vs
//! blocked vs batch-parallel comparison — come from
//! `cargo bench --bench train_step` (EXPERIMENTS.md §Perf-Train).

use std::time::Instant;

use a2q::config::RunConfig;
use a2q::coordinator::Trainer;
use a2q::datasets::{self, Split};
use a2q::perf::{self, BenchRecord};
use a2q::runtime::{NativeBackend, TrainBackend};

#[test]
fn native_train_e2e_guarantee_and_journal() {
    let quick = std::env::var("A2Q_BENCH_QUICK").map(|v| v != "0").unwrap_or(true);
    let backend = NativeBackend::new("artifacts");

    // --- e2e: full tiny-config loop, export audited against Eq. 15 ----------
    let mut cfg = RunConfig::new("mlp3", "a2q", 4, 4, 14, if quick { 24 } else { 120 });
    cfg.n_train = if quick { 192 } else { 1024 };
    cfg.n_test = 64;
    let trainer = Trainer::new(&backend, &cfg).unwrap();
    let out = trainer.run(&cfg).unwrap();
    assert!(out.guarantee_ok, "native e2e: exported layers must satisfy Eq. 15");
    assert!(out.perf.is_finite());
    assert!(out.loss_history.iter().all(|(_, l)| l.is_finite()));

    // --- smoke-scale train_step timing at the two bench grid points ---------
    let manifest = &trainer.manifest;
    let bs = manifest.batch_size;
    let ds = datasets::by_name("synth_mnist", 256, 64, 0).unwrap();
    let idx: Vec<usize> = (0..bs).collect();
    let batch = ds.gather(Split::Train, &idx);
    let macs_fwd: usize = manifest.qlayers.iter().map(|q| q.c_out * q.k).sum();
    let reps = if quick { 4 } else { 16 };

    let mut records = Vec::new();
    for (label, bits) in [("m4n4", (4u32, 4u32, 14u32)), ("m8n8", (8u32, 8u32, 20u32))] {
        let mut state = backend.init(manifest, 0.0).unwrap();
        let t0 = Instant::now();
        for _ in 0..reps {
            let loss = backend
                .train_step(manifest, "a2q", &mut state, &batch.x, &batch.y, bits, 0.05)
                .unwrap();
            assert!(loss.is_finite(), "{label}");
        }
        let dt = t0.elapsed();
        let macs = (reps * bs * macs_fwd * 3) as u64;
        println!(
            "smoke native train_step {label} (debug profile): {:.0} rows/s",
            (reps * bs) as f64 / dt.as_secs_f64().max(1e-12)
        );
        records.push(BenchRecord {
            name: format!("native_smoke/trainstep_{label}"),
            ns_per_iter: dt.as_nanos() as f64 / reps as f64,
            mac_per_s: Some(macs as f64 / dt.as_secs_f64().max(1e-12)),
            sparsity: None,
        });
    }

    // Journal under smoke-specific names; degrade gracefully from read-only
    // checkouts like the other perf instruments.
    match perf::record_benches(&records) {
        Ok(path) => {
            let journal = perf::parse_journal(&std::fs::read_to_string(path).unwrap()).unwrap();
            assert!(journal.iter().any(|r| r.name == "native_smoke/trainstep_m4n4"));
            assert!(journal.iter().any(|r| r.name == "native_smoke/trainstep_m8n8"));
        }
        Err(e) => eprintln!("perf journal not writable here ({e}); measurements printed only"),
    }
}
