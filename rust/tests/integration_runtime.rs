//! Integration tests over the real AOT artifacts: the Rust <-> HLO contract.
//! Requires the `xla` feature (PJRT engine) and `make artifacts` (skipped
//! with a message otherwise).

#![cfg(feature = "xla")]

use a2q::config::RunConfig;
use a2q::coordinator::checkpoint::Checkpoint;
use a2q::coordinator::Trainer;
use a2q::datasets::{self, Split};
use a2q::quant::a2q::l1_cap;
use a2q::runtime::{Engine, ModelManifest, TrainBackend};

fn artifacts() -> Option<&'static std::path::Path> {
    let p = std::path::Path::new("artifacts");
    if p.join("mlp.json").exists() {
        Some(p)
    } else {
        eprintln!("artifacts/ missing; run `make artifacts` (test skipped)");
        None
    }
}

#[test]
fn manifests_parse_and_validate_for_all_models() {
    let Some(dir) = artifacts() else { return };
    let models = a2q::runtime::artifact::discover_models(dir).unwrap();
    assert!(models.len() >= 5, "expected 5 models, got {models:?}");
    for m in &models {
        let manifest = ModelManifest::load(dir, m).unwrap();
        assert!(manifest.algs.contains_key("a2q"), "{m} missing a2q");
        assert!(manifest.algs.contains_key("qat"), "{m} missing qat");
        assert!(manifest.algs.contains_key("float"), "{m} missing float");
        assert!(manifest.geoms().is_ok());
        assert!(!manifest.param_indices().is_empty());
    }
}

#[test]
fn init_matches_manifest_layout_and_is_seed_dependent() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    let manifest = engine.manifest("mlp").unwrap();
    let s0 = engine.init(&manifest, 0.0).unwrap();
    let s1 = engine.init(&manifest, 1.0).unwrap();
    let t0 = s0.to_tensors().unwrap();
    let t1 = s1.to_tensors().unwrap();
    assert_eq!(t0.len(), manifest.state.len());
    for (t, meta) in t0.iter().zip(&manifest.state) {
        assert_eq!(t.shape(), &meta.shape[..], "leaf {}", meta.path);
    }
    // different seeds must give different weights (find the v leaf)
    let vi = manifest
        .state
        .iter()
        .position(|e| e.path == "params/fc/v")
        .unwrap();
    assert_ne!(t0[vi].data(), t1[vi].data(), "seed must matter");
    // same seed bit-identical
    let s0b = engine.init(&manifest, 0.0).unwrap();
    assert_eq!(t0[vi].data(), s0b.to_tensors().unwrap()[vi].data());
}

#[test]
fn train_step_decreases_loss_on_repeated_batch() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    let manifest = engine.manifest("mlp").unwrap();
    let ds = datasets::by_name("synth_mnist", 512, 64, 0).unwrap();
    let idx: Vec<usize> = (0..manifest.batch_size).collect();
    let batch = ds.gather(Split::Train, &idx);
    for alg in ["a2q", "qat", "float"] {
        let mut state = engine.init(&manifest, 0.0).unwrap();
        let mut losses = Vec::new();
        for _ in 0..12 {
            let l = engine
                .train_step(&manifest, alg, &mut state, &batch.x, &batch.y, (8, 1, 16), 0.05)
                .unwrap();
            assert!(l.is_finite());
            losses.push(l);
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "{alg}: {losses:?}"
        );
    }
}

#[test]
fn infer_output_shape_and_determinism() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    let manifest = engine.manifest("mlp").unwrap();
    let ds = datasets::by_name("synth_mnist", 256, 256, 0).unwrap();
    let idx: Vec<usize> = (0..manifest.batch_size).collect();
    let batch = ds.gather(Split::Test, &idx);
    let state = engine.init(&manifest, 0.0).unwrap();
    let a = engine.infer(&manifest, "a2q", &state, &batch.x, (8, 1, 14)).unwrap();
    let b = engine.infer(&manifest, "a2q", &state, &batch.x, (8, 1, 14)).unwrap();
    assert_eq!(a.shape(), &[manifest.batch_size, manifest.n_classes]);
    assert_eq!(a.data(), b.data(), "inference must be deterministic");
    // bits actually matter: an extreme accumulator cap changes the output
    let tight = engine.infer(&manifest, "a2q", &state, &batch.x, (8, 1, 6)).unwrap();
    assert_ne!(a.data(), tight.data(), "P must influence the a2q graph");
}

#[test]
fn export_satisfies_l1_cap_after_training_every_model() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    // mlp is cheap; cnn covers conv + depthwise geometry.
    for (model, bits) in [("mlp", (8u32, 1u32, 12u32)), ("cnn", (6, 6, 14))] {
        let mut cfg = RunConfig::new(model, "a2q", bits.0, bits.1, bits.2, 25);
        cfg.n_train = 256;
        cfg.n_test = 64;
        let trainer = Trainer::new(&engine, &cfg).unwrap();
        let out = trainer.run(&cfg).unwrap();
        assert!(out.guarantee_ok, "{model}: Eq. 15 audit failed");
        for (layer, meta) in out.exported.as_ref().unwrap().iter().zip(&trainer.manifest.qlayers)
        {
            let q = layer.to_qtensor();
            // Only runtime-P layers carry the user constraint.
            if format!("{:?}", meta.p_bits).contains("Var(\"P\")") {
                let cap = l1_cap(bits.2, bits.1, false);
                assert!(
                    q.max_l1() as f64 <= cap + 1e-6,
                    "{model}/{}: {} > {cap}",
                    layer.name,
                    q.max_l1()
                );
            }
        }
    }
}

#[test]
fn checkpoint_round_trip_preserves_eval() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    let mut cfg = RunConfig::new("mlp", "a2q", 8, 1, 16, 15);
    cfg.n_train = 256;
    cfg.n_test = 128;
    let trainer = Trainer::new(&engine, &cfg).unwrap();
    let out = trainer.run(&cfg).unwrap();
    let ckpt = Checkpoint::capture(&trainer.manifest, "a2q", 15, &out.state).unwrap();
    let tmp = a2q::testutil::TempDir::new().unwrap();
    let path = tmp.path().join("state.json");
    ckpt.save(&path).unwrap();
    let restored = Checkpoint::load(&path).unwrap().restore(&trainer.manifest).unwrap();
    let p1 = trainer.evaluate(&out.state, "a2q", cfg.bits()).unwrap();
    let p2 = trainer.evaluate(&restored, "a2q", cfg.bits()).unwrap();
    assert_eq!(p1, p2, "restore must be bit-exact");
}

#[test]
fn engine_compile_cache_reuses_executables() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    let manifest = engine.manifest("mlp").unwrap();
    assert_eq!(engine.cached(), 0);
    let _ = engine.init(&manifest, 0.0).unwrap();
    assert_eq!(engine.cached(), 1);
    let _ = engine.init(&manifest, 1.0).unwrap();
    assert_eq!(engine.cached(), 1, "same artifact must not recompile");
}

#[test]
fn a2q_integer_weights_match_rust_mirror() {
    // Cross-implementation check: the Pallas export kernel (through the
    // artifact) and the Rust mirror must agree on the integer codes given
    // the same parameters.
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(dir).unwrap();
    let manifest = engine.manifest("mlp").unwrap();
    let state = engine.init(&manifest, 3.0).unwrap();
    let bits = (8u32, 1u32, 14u32);
    let exported = engine.export(&manifest, "a2q", &state, bits).unwrap();
    let q = exported[0].to_qtensor();

    // pull v, d, t out of the state
    let tensors = state.to_tensors().unwrap();
    let find = |name: &str| {
        let i = manifest.state.iter().position(|e| e.path == name).unwrap();
        tensors[i].clone()
    };
    let v = find("params/fc/v");
    let d = find("params/fc/d");
    let t = find("params/fc/t");
    for c in 0..q.c_out {
        let (w_int, _) = a2q::quant::a2q_quantize_row(
            v.row(c),
            d.data()[c],
            t.data()[c],
            bits.0,
            bits.1,
            bits.2,
            false,
        );
        let got: Vec<i64> = q.row(c).to_vec();
        let want: Vec<i64> = w_int.iter().map(|x| *x as i64).collect();
        assert_eq!(got, want, "channel {c} mismatch between Pallas and Rust");
    }
}
