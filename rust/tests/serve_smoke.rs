//! Serving-stack smoke + property tests: the admission-control contract,
//! bit-identical micro-batching, and fault recovery — all in-process
//! against real TCP servers on ephemeral ports.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use a2q::accsim::{AccMode, IntMatrix, KernelPath, NetScratch, NetworkPlan, SharedNetworkPlan};
use a2q::json::Json;
use a2q::model::{parse_synth_spec, QNetwork};
use a2q::rng::Rng;
use a2q::serve::{
    execute_micro_batch, wire, FaultPlan, LoadgenConfig, ModelSource, ServeConfig, ServeError,
    Server, WireFormat,
};
use a2q::tensor::Tensor;

fn calibrated_net(spec: &str, seed: u64) -> QNetwork {
    let (_, net_spec) = parse_synth_spec(spec).unwrap();
    let mut net = QNetwork::synthesize(&net_spec, seed).unwrap();
    let mut rng = Rng::new(seed ^ 0xCA11);
    let k = net.input_dim();
    let data: Vec<f32> = (0..48 * k).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect();
    net.calibrate(&Tensor::new(vec![48, k], data));
    net
}

fn random_rows(rng: &mut Rng, rows: usize, cols: usize, n_bits: u32) -> IntMatrix {
    let hi = 1usize << n_bits;
    IntMatrix::from_flat(rows, cols, (0..rows * cols).map(|_| rng.below(hi) as i64).collect())
}

/// The tentpole property: serving a micro-batch is bit-identical to serving
/// each request alone — outputs and every `OverflowStats` counter — across
/// batch compositions, thread counts and forced kernel paths, both at the
/// overflow-free target P and at a deliberately narrow P where wraps fire.
#[test]
fn micro_batched_serving_is_bit_identical_to_per_request_execution() {
    let net = calibrated_net("prop:18x12x5:m4n4p16", 21);
    let p_safe = net.grid_bits().2;
    let arc = Arc::new(net);
    let paths = [
        None,
        Some(KernelPath::Scalar),
        Some(KernelPath::Simd),
        Some(KernelPath::SparseSimd),
    ];
    // Wrap at the A2Q target (no overflow) and at a starved register
    // (overflow events fire and must still be batch-invariant).
    for p_bits in [p_safe, 8] {
        let modes = [AccMode::Wrap { p_bits }];
        for (case, path) in paths.iter().enumerate() {
            let shared = SharedNetworkPlan::new_with_path(arc.clone(), &modes, *path);
            let borrowing = NetworkPlan::new_with_path(&arc, &modes, *path);
            let mut rng = Rng::new(0xBA7C + case as u64 + p_bits as u64);
            let mut scratch = NetScratch::default();
            for sizes in [vec![1usize], vec![2, 3], vec![1, 4, 2, 1], vec![5, 5, 5]] {
                let reqs: Vec<IntMatrix> =
                    sizes.iter().map(|&r| random_rows(&mut rng, r, 18, 4)).collect();
                let refs: Vec<&IntMatrix> = reqs.iter().collect();
                let tag = format!("P={p_bits} path={path:?} sizes={sizes:?}");

                // (a) The warm-scratch serving path matches threaded
                // execution of the same concatenated batch exactly.
                let total: usize = sizes.iter().sum();
                let mut flat = Vec::new();
                for r in &reqs {
                    flat.extend_from_slice(r.data());
                }
                let concat = IntMatrix::from_flat(total, 18, flat);
                let warm = shared.execute_warm(&concat, &mut scratch);
                for threads in [1usize, 2, 5] {
                    for (plan_tag, got) in [
                        ("shared", shared.execute_threads(&concat, threads)),
                        ("borrowing", borrowing.execute_threads(&concat, threads)),
                    ] {
                        assert_eq!(
                            warm[0].out.data(),
                            got[0].out.data(),
                            "{tag} {plan_tag} t={threads}"
                        );
                        assert_eq!(
                            warm[0].out_wide.data(),
                            got[0].out_wide.data(),
                            "{tag} {plan_tag} t={threads}"
                        );
                        assert_eq!(
                            warm[0].layer_stats,
                            got[0].layer_stats,
                            "{tag} {plan_tag} t={threads}"
                        );
                    }
                }

                // (b) The per-request split of the micro-batch matches each
                // request executed alone.
                let batched = execute_micro_batch(&shared, &refs, &mut scratch);
                assert_eq!(batched.total_rows, total, "{tag}");
                let mut solo_events = 0u64;
                let mut solo_dots = 0u64;
                let mut solo_macs = 0u64;
                for (ri, (req, got)) in reqs.iter().zip(&batched.per_request).enumerate() {
                    let solo = borrowing.execute(req);
                    assert_eq!(solo[0].out.data(), got.data(), "{tag} req {ri}");
                    for s in &solo[0].layer_stats {
                        solo_events += s.overflow_events;
                        solo_dots += s.dots;
                        solo_macs += s.macs;
                    }
                }
                assert_eq!(batched.overflow_events, solo_events, "{tag}");
                let warm_dots: u64 = warm[0].layer_stats.iter().map(|s| s.dots).sum();
                let warm_macs: u64 = warm[0].layer_stats.iter().map(|s| s.macs).sum();
                assert_eq!((warm_dots, warm_macs), (solo_dots, solo_macs), "{tag}");
            }
        }
    }
    // Sanity that the starved-P leg actually exercised overflow somewhere:
    // otherwise the counter assertions above prove nothing.
    let modes = [AccMode::Wrap { p_bits: 8 }];
    let shared = SharedNetworkPlan::new(arc.clone(), &modes);
    let mut rng = Rng::new(5);
    let x = random_rows(&mut rng, 16, 18, 4);
    let events: u64 = shared.execute(&x)[0].layer_stats.iter().map(|s| s.overflow_events).sum();
    assert!(events > 0, "P=8 was expected to overflow on this net; tighten the test inputs");
}

// ---------------------------------------------------------------------------
// TCP helpers
// ---------------------------------------------------------------------------

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        Client { stream, reader }
    }

    fn call(&mut self, req: Json) -> Json {
        let mut line = req.to_string();
        line.push('\n');
        self.stream.write_all(line.as_bytes()).expect("write");
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("read");
        Json::parse(&reply).expect("parse reply")
    }

    fn infer(&mut self, model: &str, rows: Vec<Vec<i64>>, deadline_ms: u64) -> Json {
        let rows = Json::arr(
            rows.into_iter()
                .map(|r| Json::Arr(r.into_iter().map(|v| Json::num(v as f64)).collect())),
        );
        self.call(Json::obj(vec![
            ("op", Json::str("infer")),
            ("model", Json::str(model)),
            ("rows", rows),
            ("deadline_ms", Json::num(deadline_ms as f64)),
        ]))
    }
}

/// Binary-protocol counterpart of [`Client`]: one reusable request frame
/// and one reply scratch per connection, the way a real binary client
/// stays allocation-free.
struct BinClient {
    stream: TcpStream,
    frame: Vec<u8>,
    scratch: Vec<u8>,
}

impl BinClient {
    fn connect(addr: std::net::SocketAddr) -> BinClient {
        BinClient {
            stream: TcpStream::connect(addr).expect("connect"),
            frame: Vec::new(),
            scratch: Vec::new(),
        }
    }

    fn infer(
        &mut self,
        hash: u64,
        rows: usize,
        cols: usize,
        codes: &[i64],
        deadline_ms: u64,
    ) -> wire::Reply {
        wire::encode_infer_request(&mut self.frame, hash, rows, cols, deadline_ms, codes);
        self.stream.write_all(&self.frame).expect("write frame");
        wire::read_reply(&mut self.stream, &mut self.scratch).expect("reply frame")
    }

    fn simple(&mut self, op: u8) -> wire::Reply {
        wire::encode_simple_request(&mut self.frame, op);
        self.stream.write_all(&self.frame).expect("write frame");
        wire::read_reply(&mut self.stream, &mut self.scratch).expect("reply frame")
    }
}

/// Binary requests address models by hash; resolve it once over JSON,
/// exactly as real binary clients are expected to.
fn model_hash(c: &mut Client, model: &str) -> u64 {
    let info = c.call(Json::obj(vec![
        ("op", Json::str("model_info")),
        ("model", Json::str(model)),
    ]));
    assert!(ok(&info), "{info:?}");
    info.get("hash").unwrap().as_str().unwrap().parse().expect("hash parses")
}

fn err_code(reply: &wire::Reply) -> &'static str {
    match reply {
        wire::Reply::Err { tag, .. } => ServeError::code_for_tag(*tag).unwrap_or("unknown_tag"),
        other => panic!("expected Reply::Err, got {other:?}"),
    }
}

fn ok(reply: &Json) -> bool {
    reply.get("ok").and_then(|v| v.as_bool()).unwrap_or(false)
}

fn code(reply: &Json) -> String {
    reply.opt("code").and_then(|c| c.as_str().ok()).unwrap_or("").to_string()
}

const SPEC: &str = "smoke:12x8x3:m4n4p16";

fn test_server(cfg: ServeConfig, fault: FaultPlan) -> Server {
    let models = [("smoke".to_string(), ModelSource::Synth(SPEC.to_string()))];
    Server::start(&cfg, &models, fault).expect("server start")
}

fn quiet_cfg() -> ServeConfig {
    ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() }
}

// ---------------------------------------------------------------------------
// End-to-end smoke
// ---------------------------------------------------------------------------

#[test]
fn tcp_round_trip_serves_inference_and_validates_requests() {
    let server = test_server(quiet_cfg(), FaultPlan::none());
    let addr = server.addr();
    let mut c = Client::connect(addr);
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("ping"))]))));

    let info = c.call(Json::obj(vec![
        ("op", Json::str("model_info")),
        ("model", Json::str("smoke")),
    ]));
    assert!(ok(&info), "{info:?}");
    assert_eq!(info.get("input_dim").unwrap().as_usize().unwrap(), 12);
    assert_eq!(info.get("output_dim").unwrap().as_usize().unwrap(), 3);

    let reply = c.infer("smoke", vec![vec![1; 12], vec![3; 12]], 1000);
    assert!(ok(&reply), "{reply:?}");
    let outputs = reply.get("outputs").unwrap().as_arr().unwrap();
    assert_eq!(outputs.len(), 2, "one output row per input row");
    assert_eq!(outputs[0].as_arr().unwrap().len(), 3);
    assert_eq!(reply.get("overflow_events").unwrap().as_u64().unwrap(), 0, "A2Q net at target P");

    // Same rows again: bit-identical replies (JSON text equality works
    // because key order and float rendering are deterministic).
    let again = c.infer("smoke", vec![vec![1; 12], vec![3; 12]], 1000);
    assert_eq!(reply.to_string(), again.to_string());

    // Typed request validation, all without dropping the connection.
    assert_eq!(code(&c.infer("nope", vec![vec![0; 12]], 100)), "unknown_model");
    assert_eq!(code(&c.infer("smoke", vec![vec![0; 11]], 100)), "bad_request");
    assert_eq!(code(&c.infer("smoke", vec![vec![99; 12]], 100)), "bad_request");
    assert_eq!(code(&c.call(Json::parse("{\"op\":\"bogus\"}").unwrap())), "bad_request");
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("ping"))]))));

    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    drop(c);
    server.join();
}

/// Overload contract under 2x+ pressure: only typed sheds, no connection
/// errors, the server keeps serving admitted work and survives to serve
/// more after the storm.
#[test]
fn overload_sheds_typed_and_server_survives() {
    let cfg = ServeConfig { queue_capacity: 2, workers: 1, max_batch_rows: 8, ..quiet_cfg() };
    // Artificial batch latency makes the 1-worker service rate far below
    // the offered load, forcing queue-full and deadline sheds.
    let server = test_server(cfg, FaultPlan::from_spec(Some("delay_ms:20")));
    let addr = server.addr();

    let report = a2q::serve::run_loadgen(&LoadgenConfig {
        addr: addr.to_string(),
        model: "smoke".to_string(),
        rps: 300.0,
        duration_ms: 700,
        connections: 3,
        rows_per_req: 2,
        deadline_ms: 120,
        connect_timeout_ms: 1000,
        seed: 9,
        wire: WireFormat::Json,
    })
    .expect("loadgen");

    assert!(report.ok > 0, "some requests must be served: {report:?}");
    assert!(
        report.shed_overloaded + report.shed_deadline > 0,
        "overload must shed typed: {report:?}"
    );
    assert_eq!(report.errors_other, 0, "no untyped failures allowed: {report:?}");
    assert_eq!(report.overflow_events, 0, "overload must never cost correctness");

    // The storm is over; the server still serves.
    let mut c = Client::connect(addr);
    let reply = c.infer("smoke", vec![vec![2; 12]], 1000);
    assert!(ok(&reply), "{reply:?}");
    let stats = c.call(Json::obj(vec![("op", Json::str("stats"))]));
    let so = stats.get("shed_overloaded").unwrap().as_u64().unwrap();
    let sd = stats.get("shed_deadline").unwrap().as_u64().unwrap();
    assert!(so > 0 || sd > 0, "server stats must record the sheds");
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    drop(c);
    server.join();
}

/// Fault isolation: an injected worker panic rejects exactly its own batch
/// with a typed error; the supervisor respawns a fresh worker and the very
/// next request is served normally.
#[test]
fn worker_panic_rejects_only_its_batch_and_respawns() {
    let cfg = ServeConfig { workers: 1, ..quiet_cfg() };
    let server = test_server(cfg, FaultPlan::from_spec(Some("panic_batch:2")));
    let addr = server.addr();
    let mut c = Client::connect(addr);

    // Sequential requests on one connection => one request per batch.
    let first = c.infer("smoke", vec![vec![1; 12]], 2000);
    assert!(ok(&first), "batch 1 precedes the fault: {first:?}");

    let second = c.infer("smoke", vec![vec![1; 12]], 2000);
    assert_eq!(code(&second), "worker_panicked", "{second:?}");
    assert_eq!(
        second.get("error").unwrap().as_str().unwrap(),
        ServeError::WorkerPanicked { batch_seq: 2 }.to_string(),
        "the typed error names the poisoned batch"
    );

    // The respawned worker serves the next request; the reply matches the
    // pre-panic reply bit for bit (fresh scratch, same plan).
    let third = c.infer("smoke", vec![vec![1; 12]], 2000);
    assert!(ok(&third), "server must keep serving after a worker panic: {third:?}");
    assert_eq!(
        first.get("outputs").unwrap().to_string(),
        third.get("outputs").unwrap().to_string()
    );

    let stats = c.call(Json::obj(vec![("op", Json::str("stats"))]));
    assert_eq!(stats.get("worker_panics").unwrap().as_u64().unwrap(), 1);
    assert_eq!(stats.get("respawns").unwrap().as_u64().unwrap(), 1);
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    drop(c);
    server.join();
}

/// Binary-protocol end-to-end: negotiation by first byte on the same
/// listener that serves JSON, framed infer round trips, typed refusals
/// that keep the connection, framing loss that closes it, and shutdown.
#[test]
fn binary_wire_round_trip_and_typed_errors() {
    let server = test_server(quiet_cfg(), FaultPlan::none());
    let addr = server.addr();
    let mut jc = Client::connect(addr);
    let hash = model_hash(&mut jc, "smoke");

    let mut b = BinClient::connect(addr);
    // A ping ack carries the drain flag and in-flight gauge (the router's
    // health probes read both); an idle server reports neither.
    assert_eq!(b.simple(wire::OP_PING), wire::Reply::Pong { draining: false, in_flight: 0 });

    let codes: Vec<i64> = (0..2 * 12).map(|i| (i % 4) as i64).collect();
    let first = match b.infer(hash, 2, 12, &codes, 1000) {
        wire::Reply::InferOk { rows, cols, overflow_events, outputs, .. } => {
            assert_eq!((rows, cols), (2, 3));
            assert_eq!(overflow_events, 0, "A2Q net at target P");
            outputs
        }
        other => panic!("expected InferOk, got {other:?}"),
    };
    // Same codes again: bit-identical reply.
    match b.infer(hash, 2, 12, &codes, 1000) {
        wire::Reply::InferOk { outputs, .. } => assert_eq!(first, outputs),
        other => panic!("expected InferOk, got {other:?}"),
    }

    // Typed refusals, each leaving the connection framed and serving.
    assert_eq!(err_code(&b.infer(hash ^ 1, 1, 12, &codes[..12], 100)), "unknown_model");
    assert_eq!(err_code(&b.infer(hash, 1, 11, &codes[..11], 100)), "bad_request");
    let mut bad_codes = codes[..12].to_vec();
    bad_codes[5] = 99;
    match b.infer(hash, 1, 12, &bad_codes, 100) {
        wire::Reply::Err { tag, message, .. } => {
            assert_eq!(ServeError::code_for_tag(tag), Some("bad_request"));
            // Same validator wording as the JSON path for the same violation.
            assert!(message.contains("row 0 code 5 = 99"), "{message}");
        }
        other => panic!("expected Reply::Err, got {other:?}"),
    }
    match b.infer(hash, 2, 12, &codes, 1000) {
        wire::Reply::InferOk { outputs, .. } => {
            assert_eq!(first, outputs, "refusals must not perturb later replies")
        }
        other => panic!("expected InferOk, got {other:?}"),
    }

    // Framing loss: a corrupt magic gets one typed error frame, then the
    // server hangs up on this connection — but only this connection.
    let mut bad_frame = Vec::new();
    wire::encode_simple_request(&mut bad_frame, wire::OP_PING);
    bad_frame[0] = b'X';
    b.stream.write_all(&bad_frame).expect("write");
    match wire::read_reply(&mut b.stream, &mut b.scratch).expect("error frame") {
        wire::Reply::Err { tag, message, .. } => {
            assert_eq!(ServeError::code_for_tag(tag), Some("bad_request"));
            assert!(message.contains("magic"), "{message}");
        }
        other => panic!("expected Reply::Err, got {other:?}"),
    }
    assert!(
        wire::read_reply(&mut b.stream, &mut b.scratch).is_err(),
        "connection must close after framing loss"
    );

    // The JSON connection on the same listener was untouched throughout.
    assert!(ok(&jc.call(Json::obj(vec![("op", Json::str("ping"))]))));

    // Shutdown over the binary protocol.
    let mut b2 = BinClient::connect(addr);
    assert_eq!(b2.simple(wire::OP_SHUTDOWN), wire::Reply::Ok { op: wire::OP_SHUTDOWN });
    drop(b2);
    drop(jc);
    server.join();
}

/// The wire-parity property: for identical requests the JSON and binary
/// protocols return bit-identical outputs and `OverflowStats` counters,
/// and identical typed error codes on refusals — across batch shapes and
/// worker counts. (Kernel-path invariance is covered at the compute layer
/// by `micro_batched_serving_is_bit_identical_to_per_request_execution`;
/// both wire encoders sit strictly above kernel dispatch.)
#[test]
fn json_and_binary_wire_paths_are_bit_identical() {
    for workers in [1usize, 3] {
        let cfg = ServeConfig { workers, ..quiet_cfg() };
        let server = test_server(cfg, FaultPlan::none());
        let addr = server.addr();
        let mut jc = Client::connect(addr);
        let hash = model_hash(&mut jc, "smoke");
        let info = jc.call(Json::obj(vec![
            ("op", Json::str("model_info")),
            ("model", Json::str("smoke")),
        ]));
        let lo = info.get("code_lo").unwrap().as_f64().unwrap() as i64;
        let hi = info.get("code_hi").unwrap().as_f64().unwrap() as i64;
        let mut b = BinClient::connect(addr);
        let mut rng = Rng::new(0xB17 + workers as u64);
        for shape in [vec![1usize], vec![2, 3], vec![1, 4, 2, 1]] {
            for rows in shape {
                let codes: Vec<i64> = (0..rows * 12)
                    .map(|_| lo + rng.below((hi - lo + 1) as usize) as i64)
                    .collect();
                let rows_json: Vec<Vec<i64>> =
                    codes.chunks(12).map(|r| r.to_vec()).collect();
                let jreply = jc.infer("smoke", rows_json, 1000);
                assert!(ok(&jreply), "{jreply:?}");
                let joutputs = jreply.get("outputs").unwrap().as_arr().unwrap();
                let joverflow = jreply.get("overflow_events").unwrap().as_u64().unwrap();
                match b.infer(hash, rows, 12, &codes, 1000) {
                    wire::Reply::InferOk { rows: br, cols: bc, overflow_events, outputs, .. } => {
                        assert_eq!((br, bc), (rows, 3), "w={workers} rows={rows}");
                        assert_eq!(overflow_events, joverflow, "w={workers} rows={rows}");
                        for r in 0..rows {
                            let jrow = joutputs[r].as_arr().unwrap();
                            assert_eq!(jrow.len(), 3);
                            for c in 0..3 {
                                // JSON floats render shortest-round-trip, so
                                // parsing back gives exactly `f32 as f64`.
                                let jv = jrow[c].as_f64().unwrap();
                                let bv = outputs[r * 3 + c] as f64;
                                assert_eq!(
                                    jv.to_bits(),
                                    bv.to_bits(),
                                    "w={workers} rows={rows} r={r} c={c}: json {jv} vs binary {bv}"
                                );
                            }
                        }
                    }
                    other => panic!("expected InferOk, got {other:?}"),
                }
            }
        }
        // Error-code parity for the same violations.
        assert_eq!(code(&jc.infer("nope", vec![vec![lo; 12]], 100)), "unknown_model");
        assert_eq!(err_code(&b.infer(hash ^ 1, 1, 12, &[lo; 12], 100)), "unknown_model");
        assert_eq!(code(&jc.infer("smoke", vec![vec![hi + 1; 12]], 100)), "bad_request");
        assert_eq!(err_code(&b.infer(hash, 1, 12, &[hi + 1; 12], 100)), "bad_request");

        assert_eq!(b.simple(wire::OP_SHUTDOWN), wire::Reply::Ok { op: wire::OP_SHUTDOWN });
        drop(b);
        drop(jc);
        server.join();
    }
}

/// The zero-loss drain contract on a single replica: a drained server
/// refuses new work with the typed `draining` code on both protocols,
/// reports the drain flag through ping (JSON and binary pong), and
/// resumes serving bit-identically after `resume`.
#[test]
fn drain_refuses_typed_reports_state_and_resume_readmits() {
    let server = test_server(quiet_cfg(), FaultPlan::none());
    let addr = server.addr();
    let mut c = Client::connect(addr);
    let hash = model_hash(&mut c, "smoke");
    let before = c.infer("smoke", vec![vec![1; 12]], 1000);
    assert!(ok(&before), "{before:?}");

    // JSON drain: the ack and subsequent pings report draining=true with
    // the in-flight gauge a router watches bleed to zero.
    let drained = c.call(Json::obj(vec![("op", Json::str("drain"))]));
    assert!(ok(&drained), "{drained:?}");
    assert!(drained.get("draining").unwrap().as_bool().unwrap());
    assert_eq!(drained.get("in_flight").unwrap().as_u64().unwrap(), 0);
    let pong = c.call(Json::obj(vec![("op", Json::str("ping"))]));
    assert!(pong.get("draining").unwrap().as_bool().unwrap(), "{pong:?}");

    // Both protocols shed new work typed; neither connection drops.
    assert_eq!(code(&c.infer("smoke", vec![vec![1; 12]], 1000)), "draining");
    let mut b = BinClient::connect(addr);
    assert_eq!(b.simple(wire::OP_PING), wire::Reply::Pong { draining: true, in_flight: 0 });
    let codes = vec![1i64; 12];
    assert_eq!(err_code(&b.infer(hash, 1, 12, &codes, 1000)), "draining");

    // Binary resume ack; the very next request serves bit-identically.
    assert_eq!(b.simple(wire::OP_RESUME), wire::Reply::Ok { op: wire::OP_RESUME });
    let after = c.infer("smoke", vec![vec![1; 12]], 1000);
    assert_eq!(before.to_string(), after.to_string(), "drain/resume must not perturb replies");

    // Binary drain ack flips the flag right back.
    assert_eq!(b.simple(wire::OP_DRAIN), wire::Reply::Ok { op: wire::OP_DRAIN });
    assert_eq!(code(&c.infer("smoke", vec![vec![1; 12]], 1000)), "draining");
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("resume"))]))));

    let stats = c.call(Json::obj(vec![("op", Json::str("stats"))]));
    assert!(stats.get("shed_draining").unwrap().as_u64().unwrap() >= 2, "{stats:?}");
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    drop(c);
    drop(b);
    server.join();
}

/// The slow-loris defence: a connection that sends no request bytes for
/// the idle timeout gets a typed `idle_timeout` close — on the very first
/// byte (binary error frame, protocol not yet negotiated) and mid-stream
/// on an established JSON session — while fresh connections still serve.
#[test]
fn idle_connections_close_typed_and_server_keeps_serving() {
    let cfg = ServeConfig { idle_timeout_ms: 150, ..quiet_cfg() };
    let server = test_server(cfg, FaultPlan::none());
    let addr = server.addr();

    // Totally silent connection: the first-byte read times out before the
    // protocol is even negotiated; the typed close arrives as a binary
    // error frame.
    let mut silent = TcpStream::connect(addr).expect("connect");
    let mut scratch = Vec::new();
    match wire::read_reply(&mut silent, &mut scratch).expect("typed close frame") {
        wire::Reply::Err { tag, message, .. } => {
            assert_eq!(ServeError::code_for_tag(tag), Some("idle_timeout"));
            assert!(message.contains("150"), "{message}");
        }
        other => panic!("expected Reply::Err, got {other:?}"),
    }
    assert!(
        wire::read_reply(&mut silent, &mut scratch).is_err(),
        "connection must close after the typed idle_timeout"
    );

    // Established JSON session that goes quiet: typed line, then EOF.
    let mut c = Client::connect(addr);
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("ping"))]))));
    let mut line = String::new();
    c.reader.read_line(&mut line).expect("typed close line");
    let close = Json::parse(&line).expect("typed close parses");
    assert_eq!(code(&close), "idle_timeout", "{close:?}");
    line.clear();
    assert_eq!(c.reader.read_line(&mut line).unwrap_or(0), 0, "EOF after typed close");

    // The server itself is unharmed: a fresh, active connection serves.
    let mut fresh = Client::connect(addr);
    let reply = fresh.infer("smoke", vec![vec![2; 12]], 1000);
    assert!(ok(&reply), "{reply:?}");
    assert!(ok(&fresh.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    drop(fresh);
    server.join();
}

/// An injected cache-load failure is a per-request typed error on an
/// otherwise healthy server.
#[test]
fn cache_load_fault_fails_requests_typed_not_the_server() {
    let server = test_server(quiet_cfg(), FaultPlan::from_spec(Some("cache_load")));
    let addr = server.addr();
    let mut c = Client::connect(addr);
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("ping"))]))));
    let reply = c.call(Json::obj(vec![
        ("op", Json::str("model_info")),
        ("model", Json::str("smoke")),
    ]));
    assert_eq!(code(&reply), "load_failed", "{reply:?}");
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("ping"))]))));
    assert!(ok(&c.call(Json::obj(vec![("op", Json::str("shutdown"))]))));
    drop(c);
    server.join();
}
