//! Offline, dependency-free subset of the `anyhow` crate API.
//!
//! The a2q build must work with no network and no registry cache, so the
//! workspace vendors this drop-in shim instead of depending on crates.io.
//! It covers exactly the surface the codebase uses:
//!
//! * [`Error`] / [`Result`] — a single-string error that captures the
//!   `Display` chain of whatever it was built from;
//! * `From<E: std::error::Error>` so `?` works on io/parse/etc. errors;
//! * the [`Context`] extension trait (`.context(...)` / `.with_context(...)`)
//!   on both `Result` and `Option`;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Differences from the real crate: no backtraces, no downcasting, and the
//! source chain is flattened into the message at construction time. None of
//! those are used here.

use std::fmt;

/// A flattened error message (optionally with the source chain appended).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (the `anyhow!` macro core).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Construct from a std error, appending its source chain.
    pub fn new<E: std::error::Error>(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }

    /// Prepend a context line, matching anyhow's `{context}: {cause}` shape
    /// when rendered on one line.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `Result::unwrap` and `fn main() -> Result<()>` render via Debug;
        // show the human message, as real anyhow does.
        f.write_str(&self.msg)
    }
}

// NOTE: like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket From possible.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i64> {
        Ok(s.parse::<i64>()?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_num("42").unwrap(), 42);
        let e = parse_num("nope").unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn context_prepends() {
        let r: Result<()> = Err(anyhow!("inner")).context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: inner");
        let o: Result<i32> = None.with_context(|| format!("missing {}", 7));
        assert_eq!(o.unwrap_err().to_string(), "missing 7");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
