//! Stub of the xla-rs PJRT binding surface the a2q runtime layer uses.
//!
//! The real bindings need the XLA extension shared library, which is not
//! present in offline build environments. This stub keeps the `--features
//! xla` configuration *compiling* everywhere:
//!
//! * [`Literal`] is fully functional (host-side f32 buffer + dims), so the
//!   tensor <-> literal transport and its tests work;
//! * everything that would actually touch PJRT ([`PjRtClient::cpu`],
//!   [`HloModuleProto::from_text_file`], executions) returns a descriptive
//!   [`Error`] at runtime.
//!
//! Deploying for real means replacing this path dependency with the actual
//! xla-rs bindings (identical API subset) via `[patch]` or by editing
//! `rust/Cargo.toml`; no a2q source changes are needed.

use std::fmt;
use std::path::Path;

const STUB_MSG: &str =
    "XLA/PJRT backend unavailable: built against the vendored stub (see rust/vendor/xla)";

/// Error type mirroring xla-rs: displayable and usable with `?`/anyhow.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(Error(format!("{what}: {STUB_MSG}")))
}

/// Element types the host transport understands (the artifact interface is
/// all-f32, so only f32 is implemented).
pub trait NativeType: Sized + Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// Dense array shape (dims in elements, row-major).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host literal: f32 buffer + dims. Functional in the stub so the
/// Tensor <-> Literal round trip (and its tests) work without PJRT.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch ({} vs {n})",
                self.dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|v| T::from_f32(*v)).collect())
    }

    /// Decompose a tuple literal. The stub never produces tuples (they only
    /// come back from executions), so this always errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub_err("Literal::to_tuple")
    }
}

/// Device buffer handle returned by executions (never constructible here).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        stub_err(&format!("parsing HLO text {:?}", path.as_ref()))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        // Unreachable in practice: an HloModuleProto cannot be constructed
        // from the stub. Kept total so call sites compile unchanged.
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client. `cpu()` fails in the stub with a clear message.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn pjrt_paths_fail_loudly() {
        let e = PjRtClient::cpu().err().unwrap().to_string();
        assert!(e.contains("vendored stub"), "{e}");
    }
}
