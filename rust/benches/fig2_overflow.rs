//! Bench + regeneration of paper Fig. 2: overflow impact on the 1-layer
//! binary-MNIST QNN. Times the accsim hot loop (the bit-exact P-bit
//! register simulation) on the Fig. 2 shape — per-mode single calls plus
//! the fused all-widths sweep — and regenerates a reduced fig2.csv end to
//! end (training included) through the native backend, no artifacts or XLA
//! toolchain required.

#[path = "harness.rs"]
mod harness;

use a2q::accsim::matmul::quantize_inputs;
use a2q::accsim::{qlinear_forward, qlinear_forward_multi, qlinear_forward_ref, AccMode};
use a2q::datasets::{synth_mnist, Split};
use a2q::quant::QTensor;
use a2q::rng::Rng;
use a2q::tensor::Tensor;

fn synthetic_layer(k: usize, c_out: usize, seed: u64) -> QTensor {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..c_out * k)
        .map(|_| (rng.normal() * 40.0).round().clamp(-128.0, 127.0) as f32)
        .collect();
    QTensor::from_export(
        &Tensor::new(vec![c_out, k], w),
        &Tensor::new(vec![c_out, 1], vec![0.01; c_out]),
        &Tensor::from_vec(vec![0.0; c_out]),
    )
}

fn main() {
    let mut journal = harness::Journal::new();

    // --- microbench: the accsim inner loop over the Fig. 2 shape ------------
    let ds = synth_mnist::generate(0, 256, 0);
    let idx: Vec<usize> = (0..256).collect();
    let batch = ds.gather(Split::Test, &idx);
    let x_int = quantize_inputs(&batch.x, 1.0, 1, false);
    let layer = synthetic_layer(synth_mnist::DIM, 2, 1);
    let macs = (x_int.rows() * layer.c_out * layer.k) as u64;

    for (name, mode) in [
        ("wide", AccMode::Wide),
        ("wrap_p14", AccMode::Wrap { p_bits: 14 }),
        ("saturate_p14", AccMode::Saturate { p_bits: 14 }),
    ] {
        let r = harness::bench(&format!("fig2/accsim_{name}_256x2x784"), 2, 10, || {
            qlinear_forward(&x_int, 1.0, &layer, mode)
        });
        println!("  ({:.1} M MAC/s)", harness::throughput(&r, macs) / 1e6);
        journal.add(&r, Some(macs));
    }

    // --- microbench: the Fig. 2 P-sweep, scalar-per-P vs fused -------------
    let p_values: Vec<u32> = (10..=20).collect();
    let modes: Vec<AccMode> = p_values
        .iter()
        .flat_map(|&p| [AccMode::Wrap { p_bits: p }, AccMode::Saturate { p_bits: p }])
        .collect();
    let sweep_macs = macs * modes.len() as u64;
    let rb = harness::bench("fig2/psweep_scalar_baseline", 1, 5, || {
        modes
            .iter()
            .map(|m| qlinear_forward_ref(&x_int, 1.0, &layer, *m).stats.overflow_events)
            .sum::<u64>()
    });
    println!("  ({:.1} M MAC/s)", harness::throughput(&rb, sweep_macs) / 1e6);
    journal.add(&rb, Some(sweep_macs));
    let rf = harness::bench("fig2/psweep_fused_engine", 1, 5, || {
        qlinear_forward_multi(&x_int, 1.0, &layer, &modes)
            .iter()
            .map(|s| s.stats.overflow_events)
            .sum::<u64>()
    });
    println!("  ({:.1} M MAC/s)", harness::throughput(&rf, sweep_macs) / 1e6);
    journal.add(&rf, Some(sweep_macs));
    println!(
        "fig2 sweep: fused {:.1}x over per-P scalar ({} modes)",
        rb.median.as_secs_f64() / rf.median.as_secs_f64(),
        modes.len()
    );
    journal.flush();

    // --- end-to-end figure regeneration (native backend by default) ---------
    end_to_end();
}

fn end_to_end() {
    use a2q::report::fig2;
    use a2q::runtime::{make_backend, BackendKind};

    let steps = if harness::quick() { 60 } else { 250 };
    let backend = make_backend(BackendKind::Native, "artifacts".as_ref()).expect("backend");
    let p_values: Vec<u32> = vec![10, 12, 14, 16, 18, 20];
    let t0 = std::time::Instant::now();
    let rep = fig2::run(backend.as_ref(), &p_values, steps, 256, 0).expect("fig2 run");
    fig2::emit(&rep, std::path::Path::new("results")).expect("emit");
    println!(
        "fig2 end-to-end ({} trainings + sims) in {:.1}s; wide acc {:.4}",
        p_values.len() + 1,
        t0.elapsed().as_secs_f64(),
        rep.acc_wide
    );
    // Paper-shape checks: overflow rate decreases with P; A2Q never overflows
    // and beats wraparound at the lowest P.
    for w in rep.rows.windows(2) {
        assert!(w[0].overflow_rate_wrap >= w[1].overflow_rate_wrap);
    }
    assert!(rep.rows.iter().all(|r| r.a2q_overflows == 0));
    let lowest = &rep.rows[0];
    assert!(lowest.acc_a2q >= lowest.acc_wrap);
    println!("fig2 invariants hold (monotone overflow rate, A2Q overflow-free & dominant at low P)");
}
