//! Bench + regeneration of paper Fig. 4: the perf-vs-accumulator Pareto
//! frontiers. Consumes sweep records (results/runs.jsonl, produced by
//! `a2q sweep`); if absent, runs a reduced inline sweep on the mlp so the
//! bench is self-contained.

#[path = "harness.rs"]
mod harness;

use std::collections::BTreeMap;
#[cfg(feature = "xla")]
use std::path::PathBuf;

use a2q::coordinator::MetricsSink;
use a2q::pareto::frontier_dominates;
use a2q::report::fig45;
use a2q::runtime::ModelManifest;

/// Fall back to a reduced inline sweep when no records exist (needs the
/// PJRT engine, so `xla` builds only).
#[cfg(feature = "xla")]
fn inline_sweep() -> Option<Vec<a2q::coordinator::RunRecord>> {
    use a2q::config::SweepConfig;
    println!("no sweep records; running a reduced inline mlp sweep");
    let mut cfg =
        SweepConfig::default_grid(vec!["mlp".into()], if harness::quick() { 40 } else { 200 });
    cfg.algs.push("float".into());
    cfg.mn_values = vec![8];
    Some(
        a2q::coordinator::run_sweep(
            cfg,
            PathBuf::from("artifacts"),
            PathBuf::from("results/runs.jsonl"),
            false,
        )
        .expect("inline sweep"),
    )
}

#[cfg(not(feature = "xla"))]
fn inline_sweep() -> Option<Vec<a2q::coordinator::RunRecord>> {
    println!("no sweep records and no `xla` feature; run `a2q sweep` first");
    None
}

fn main() {
    let sink = MetricsSink::new("results/runs.jsonl");
    let mut records = sink.load().expect("sink parse");
    if records.is_empty() {
        match inline_sweep() {
            Some(r) => records = r,
            None => return,
        }
    }

    let mut largest_k = BTreeMap::new();
    let mut models: Vec<String> = records.iter().map(|r| r.config.model.clone()).collect();
    models.sort();
    models.dedup();
    for m in &models {
        let manifest = ModelManifest::load(std::path::Path::new("artifacts"), m).expect("manifest");
        largest_k.insert(m.clone(), manifest.largest_k);
    }

    // Time the frontier construction over the full record set.
    let r = harness::bench("fig4/frontiers_from_records", 2, 20, || {
        fig45::fig4(&records, &largest_k)
    });
    println!("  ({} records -> {} models)", records.len(), models.len());
    let _ = r;

    let f4 = fig45::fig4(&records, &largest_k);
    fig45::emit_fig4(&f4, std::path::Path::new("results")).expect("emit");
    for m in &f4 {
        // Paper headline: A2Q reaches strictly lower P than the QAT heuristic
        // while remaining on the frontier.
        let a2q = m.frontiers.iter().find(|(a, _)| a == "a2q");
        let qat = m.frontiers.iter().find(|(a, _)| a == "qat");
        if let (Some((_, af)), Some((_, qf))) = (a2q, qat) {
            let a2q_min_p = af.iter().map(|p| p.cost).fold(f64::INFINITY, f64::min);
            let qat_min_p = qf.iter().map(|p| p.cost).fold(f64::INFINITY, f64::min);
            println!(
                "{:<8} A2Q min P {:>4}  QAT min safe P {:>4}  dominance(A2Q>=QAT): {}",
                m.model,
                a2q_min_p,
                qat_min_p,
                frontier_dominates(af, qf, 1e-9)
            );
            assert!(
                a2q_min_p <= qat_min_p,
                "{}: A2Q must reach at least as low an accumulator",
                m.model
            );
        }
    }
    println!("wrote results/fig4_*.csv");
}
