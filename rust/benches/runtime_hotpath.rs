//! Runtime hot-path microbenchmarks (the EXPERIMENTS.md §Perf instrument):
//!
//! * accsim MAC throughput (the figure substrate): single-dot register
//!   models, plus the headline 25-width P-sweep — per-P scalar baseline vs
//!   the fused multi-P kernel engine (bound-gated + scoped threads);
//! * dataset batch materialization;
//! * `train_step` latency per model/alg and the PJRT dispatch path (needs
//!   the `xla` feature + AOT artifacts).
//!
//! Results are journaled to BENCH_accsim.json and the auto-recorded block
//! of EXPERIMENTS.md §Perf via `a2q::perf`.

#[path = "harness.rs"]
mod harness;

use a2q::accsim::{
    dot_accumulate, qlinear_forward_multi, qlinear_forward_ref, AccMode, IntMatrix,
};
use a2q::datasets::{self, Split};
use a2q::rng::Rng;
use a2q::testutil::{psweep_constrained_layer, psweep_layer};

/// The P-sweep every figure replays: 25 accumulator widths.
const P_SWEEP: std::ops::RangeInclusive<u32> = 8..=32;

fn main() {
    let mut journal = harness::Journal::new();

    // --- accsim dot throughput ----------------------------------------------
    let mut rng = Rng::new(1);
    let k = 4096;
    let x: Vec<i64> = (0..k).map(|_| rng.below(256) as i64).collect();
    let w: Vec<i64> = (0..k).map(|_| rng.below(255) as i64 - 127).collect();
    for (name, mode) in [
        ("wide", AccMode::Wide),
        ("wrap16", AccMode::Wrap { p_bits: 16 }),
        ("sat16", AccMode::Saturate { p_bits: 16 }),
    ] {
        let r = harness::bench(&format!("accsim/dot_{name}_k4096_x1000"), 3, 20, || {
            let mut acc = 0i64;
            for _ in 0..1000 {
                acc ^= dot_accumulate(&x, &w, mode).value;
            }
            acc
        });
        let macs = 1000 * k as u64;
        println!("  ({:.0} M MAC/s)", harness::throughput(&r, macs) / 1e6);
        journal.add(&r, Some(macs));
    }

    // --- accsim P-sweep: per-P scalar baseline vs fused engine ---------------
    // The shape every sweep figure hits: a quantized layer forwarded under
    // all 25 accumulator widths. Baseline walks the MACs once per width;
    // the engine walks them once total.
    let (batch, c_out, kk) = if harness::quick() { (16, 16, 512) } else { (64, 64, 1024) };
    let layer = psweep_layer(c_out, kk, 7);
    let mut xrng = Rng::new(8);
    let xm = IntMatrix::from_flat(
        batch,
        kk,
        (0..batch * kk).map(|_| xrng.below(256) as i64).collect(),
    );
    let modes: Vec<AccMode> = P_SWEEP.map(|p| AccMode::Wrap { p_bits: p }).collect();
    let sweep_macs = (modes.len() * batch * c_out * kk) as u64;
    let iters = if harness::quick() { 3 } else { 10 };

    let rb = harness::bench("accsim/psweep25_scalar_baseline", 1, iters, || {
        let mut events = 0u64;
        for mode in &modes {
            events += qlinear_forward_ref(&xm, 1.0, &layer, *mode).stats.overflow_events;
        }
        events
    });
    println!("  ({:.0} M MAC/s)", harness::throughput(&rb, sweep_macs) / 1e6);
    journal.add(&rb, Some(sweep_macs));

    let rf = harness::bench("accsim/psweep25_fused_engine", 1, iters, || {
        qlinear_forward_multi(&xm, 1.0, &layer, &modes)
            .iter()
            .map(|s| s.stats.overflow_events)
            .sum::<u64>()
    });
    println!("  ({:.0} M MAC/s)", harness::throughput(&rf, sweep_macs) / 1e6);
    journal.add(&rf, Some(sweep_macs));

    let speedup = rb.median.as_secs_f64() / rf.median.as_secs_f64();
    println!(
        "accsim P-sweep ({} widths, batch {batch} x c_out {c_out} x k {kk}): fused engine {speedup:.1}x over per-P scalar",
        modes.len()
    );
    journal.flush();

    // Refresh the auto-recorded §Perf block of EXPERIMENTS.md.
    let block = a2q::perf::render_psweep_block(
        &format!(
            "`cargo bench --bench runtime_hotpath`{}",
            if harness::quick() { " (quick mode)" } else { "" }
        ),
        &harness::to_record(&rb, Some(sweep_macs)),
        &harness::to_record(&rf, Some(sweep_macs)),
        &format!("{} widths, batch {batch} x c_out {c_out} x k {kk}", modes.len()),
    );
    match a2q::perf::update_experiments_block(&block) {
        Ok(true) => println!("EXPERIMENTS.md §Perf block updated"),
        Ok(false) => println!("EXPERIMENTS.md markers absent; skipped §Perf update"),
        Err(e) => eprintln!("EXPERIMENTS.md update failed: {e}"),
    }

    // --- accsim P-sweep on the A2Q-constrained shape: the headline case ------
    // A layer quantized at target P = 16 swept at/above its target: the
    // Eq. 15 cap makes every channel provably safe, so the partitioned
    // engine drives the whole grid through the packed blocked GEMM with
    // zero register simulation.
    let clayer = psweep_constrained_layer(c_out, kk, 16, 8, 7);
    let cmodes: Vec<AccMode> = (16..=40).map(|p| AccMode::Wrap { p_bits: p }).collect();
    let cmacs = (cmodes.len() * batch * c_out * kk) as u64;

    let rcb = harness::bench("accsim/psweep25_constrained_scalar", 1, iters, || {
        let mut events = 0u64;
        for mode in &cmodes {
            events += qlinear_forward_ref(&xm, 1.0, &clayer, *mode).stats.overflow_events;
        }
        events
    });
    println!("  ({:.0} M MAC/s)", harness::throughput(&rcb, cmacs) / 1e6);
    journal.add(&rcb, Some(cmacs));

    let rcf = harness::bench("accsim/psweep25_constrained_gemm", 1, iters, || {
        qlinear_forward_multi(&xm, 1.0, &clayer, &cmodes)
            .iter()
            .map(|s| s.stats.overflow_events)
            .sum::<u64>()
    });
    println!("  ({:.0} M MAC/s)", harness::throughput(&rcf, cmacs) / 1e6);
    journal.add(&rcf, Some(cmacs));
    println!(
        "accsim constrained P-sweep ({} widths at/above target, batch {batch} x c_out {c_out} x k {kk}): \
         safe-span GEMM engine {:.1}x over per-P scalar",
        cmodes.len(),
        rcb.median.as_secs_f64() / rcf.median.as_secs_f64().max(1e-12)
    );
    journal.flush();

    // --- kernel dispatch on a tightly-constrained (sparse) layer -------------
    // P = 14 with 8-bit inputs squeezes each row's l1 budget to ≈32 codes,
    // so the A2Q quantizer leaves most weights at zero — the regime where
    // the sparse packed panels should beat the dense blocked kernel. Same
    // plan run under each forced path, threads pinned to 1 so the journal
    // compares kernels, not scheduling.
    let tlayer = psweep_constrained_layer(c_out, kk, 14, 8, 7);
    let tsparsity = tlayer.sparsity();
    assert!(tsparsity >= 0.70, "tight fixture must be mostly zeros, got {tsparsity:.3}");
    let tmodes: Vec<AccMode> = (14..=38).map(|p| AccMode::Wrap { p_bits: p }).collect();
    let tmacs = (tmodes.len() * batch * c_out * kk) as u64;
    for (label, path) in [
        ("scalar", a2q::accsim::KernelPath::Scalar),
        ("simd", a2q::accsim::KernelPath::Simd),
        ("sparse", a2q::accsim::KernelPath::SparseSimd),
    ] {
        let plan = a2q::accsim::LayerPlan::new_with_path(&tlayer, &tmodes, Some(path));
        let rt = harness::bench(&format!("accsim/kpath_tight_{label}"), 1, iters, || {
            plan.execute_threads(&xm, 1.0, 1)
                .iter()
                .map(|s| s.stats.overflow_events)
                .sum::<u64>()
        });
        println!(
            "  ({:.0} M MAC/s, weight sparsity {tsparsity:.3})",
            harness::throughput(&rt, tmacs) / 1e6
        );
        journal.add_sparse(&rt, Some(tmacs), Some(tsparsity));
    }
    journal.flush();

    // --- dataset batch materialization --------------------------------------
    let ds = datasets::by_name("synth_cifar", 2048, 512, 0).unwrap();
    let mut drng = Rng::new(2);
    let r = harness::bench("datasets/cifar_epoch_bs64", 2, 20, || {
        let batches = ds.epoch(Split::Train, 64, &mut drng);
        batches.iter().map(|idx| ds.gather(Split::Train, idx).x.len()).sum::<usize>()
    });
    let _ = r;

    // --- PJRT request path (xla feature + artifacts only) --------------------
    #[cfg(feature = "xla")]
    pjrt_benches();
    #[cfg(not(feature = "xla"))]
    println!("built without the `xla` feature; skipping PJRT hot-path benches");
}

#[cfg(feature = "xla")]
fn pjrt_benches() {
    use a2q::config::RunConfig;
    use a2q::runtime::{Engine, TrainBackend};

    if !std::path::Path::new("artifacts/mlp.json").exists() {
        println!("artifacts missing; skipping PJRT hot-path benches");
        return;
    }
    let engine = Engine::new("artifacts").expect("engine");
    for (model, alg) in [("mlp", "a2q"), ("mlp", "qat"), ("cnn", "a2q"), ("espcn", "a2q")] {
        let manifest = engine.manifest(model).expect("manifest");
        let cfg = RunConfig::new(model, alg, 6, 6, 16, 1);
        let ds = datasets::by_name(datasets::default_for_model(model), 512, 64, 0).unwrap();
        let idx: Vec<usize> = (0..manifest.batch_size).collect();
        let batch = ds.gather(Split::Train, &idx);
        let mut state = engine.init(&manifest, 0.0).expect("init");
        // one unmeasured step compiles the executable
        engine
            .train_step(&manifest, alg, &mut state, &batch.x, &batch.y, cfg.bits(), 0.01)
            .expect("warm step");
        let iters = if harness::quick() { 5 } else { 30 };
        let r = harness::bench(&format!("runtime/train_step_{model}_{alg}"), 2, iters, || {
            engine
                .train_step(&manifest, alg, &mut state, &batch.x, &batch.y, cfg.bits(), 0.01)
                .expect("step")
        });
        let _ = r;
    }

    // infer path
    let manifest = engine.manifest("mlp").expect("manifest");
    let ds = datasets::by_name("synth_mnist", 512, 256, 0).unwrap();
    let idx: Vec<usize> = (0..manifest.batch_size).collect();
    let batch = ds.gather(Split::Test, &idx);
    let state = engine.init(&manifest, 0.0).expect("init");
    engine.infer(&manifest, "a2q", &state, &batch.x, (8, 1, 16)).expect("warm");
    let r = harness::bench("runtime/infer_mlp_a2q_bs128", 2, 30, || {
        engine.infer(&manifest, "a2q", &state, &batch.x, (8, 1, 16)).expect("infer")
    });
    println!(
        "  ({:.0} samples/s)",
        harness::throughput(&r, manifest.batch_size as u64)
    );
}
