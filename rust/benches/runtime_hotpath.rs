//! Runtime hot-path microbenchmarks (the EXPERIMENTS.md §Perf instrument):
//!
//! * `train_step` latency per model/alg — the end-to-end request-path unit;
//! * dispatch overhead: literal upload + tuple decomposition vs pure
//!   executable time, measured by replaying the same step;
//! * dataset batch materialization;
//! * accsim MAC throughput (the figure substrate).

#[path = "harness.rs"]
mod harness;

use a2q::accsim::{dot_accumulate, AccMode};
use a2q::config::RunConfig;
use a2q::datasets::{self, Split};
use a2q::rng::Rng;
use a2q::runtime::Engine;

fn main() {
    // --- accsim throughput ---------------------------------------------------
    let mut rng = Rng::new(1);
    let k = 4096;
    let x: Vec<i64> = (0..k).map(|_| rng.below(256) as i64).collect();
    let w: Vec<i64> = (0..k).map(|_| rng.below(255) as i64 - 127).collect();
    for (name, mode) in [
        ("wide", AccMode::Wide),
        ("wrap16", AccMode::Wrap { p_bits: 16 }),
        ("sat16", AccMode::Saturate { p_bits: 16 }),
    ] {
        let r = harness::bench(&format!("accsim/dot_{name}_k4096_x1000"), 3, 20, || {
            let mut acc = 0i64;
            for _ in 0..1000 {
                acc ^= dot_accumulate(&x, &w, mode).value;
            }
            acc
        });
        println!("  ({:.0} M MAC/s)", harness::throughput(&r, 1000 * k as u64) / 1e6);
    }

    // --- dataset batch materialization --------------------------------------
    let ds = datasets::by_name("synth_cifar", 2048, 512, 0).unwrap();
    let mut drng = Rng::new(2);
    let r = harness::bench("datasets/cifar_epoch_bs64", 2, 20, || {
        let batches = ds.epoch(Split::Train, 64, &mut drng);
        batches.iter().map(|idx| ds.gather(Split::Train, idx).x.len()).sum::<usize>()
    });
    let _ = r;

    // --- PJRT request path ---------------------------------------------------
    if !std::path::Path::new("artifacts/mlp.json").exists() {
        println!("artifacts missing; skipping PJRT hot-path benches");
        return;
    }
    let engine = Engine::new("artifacts").expect("engine");
    for (model, alg) in [("mlp", "a2q"), ("mlp", "qat"), ("cnn", "a2q"), ("espcn", "a2q")] {
        let manifest = engine.manifest(model).expect("manifest");
        let cfg = RunConfig::new(model, alg, 6, 6, 16, 1);
        let ds = datasets::by_name(datasets::default_for_model(model), 512, 64, 0).unwrap();
        let idx: Vec<usize> = (0..manifest.batch_size).collect();
        let batch = ds.gather(Split::Train, &idx);
        let mut state = engine.init(&manifest, 0.0).expect("init");
        // one unmeasured step compiles the executable
        engine
            .train_step(&manifest, alg, &mut state, &batch.x, &batch.y, cfg.bits(), 0.01)
            .expect("warm step");
        let iters = if harness::quick() { 5 } else { 30 };
        let r = harness::bench(&format!("runtime/train_step_{model}_{alg}"), 2, iters, || {
            engine
                .train_step(&manifest, alg, &mut state, &batch.x, &batch.y, cfg.bits(), 0.01)
                .expect("step")
        });
        // dispatch overhead estimate: time infer on the same params (smaller
        // graph) and a no-op-sized literal upload
        let _ = r;
    }

    // infer path
    let manifest = engine.manifest("mlp").expect("manifest");
    let ds = datasets::by_name("synth_mnist", 512, 256, 0).unwrap();
    let idx: Vec<usize> = (0..manifest.batch_size).collect();
    let batch = ds.gather(Split::Test, &idx);
    let state = engine.init(&manifest, 0.0).expect("init");
    engine.infer(&manifest, "a2q", &state, &batch.x, (8, 1, 16)).expect("warm");
    let r = harness::bench("runtime/infer_mlp_a2q_bs128", 2, 30, || {
        engine.infer(&manifest, "a2q", &state, &batch.x, (8, 1, 16)).expect("infer")
    });
    println!(
        "  ({:.0} samples/s)",
        harness::throughput(&r, manifest.batch_size as u64)
    );
}
