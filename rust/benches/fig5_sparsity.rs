//! Bench + regeneration of paper Fig. 5: sparsity and relative accuracy vs
//! accumulator width, aggregated across models. Consumes sweep records.

#[path = "harness.rs"]
mod harness;

use a2q::coordinator::MetricsSink;
use a2q::report::fig45;

fn main() {
    let sink = MetricsSink::new("results/runs.jsonl");
    let records = sink.load().expect("sink parse");
    if records.is_empty() {
        println!("no sweep records at results/runs.jsonl; run `a2q sweep` first");
        return;
    }

    let r = harness::bench("fig5/aggregate_from_records", 2, 50, || fig45::fig5(&records));
    let _ = r;

    let rows = fig45::fig5(&records);
    fig45::emit_fig5(&rows, std::path::Path::new("results")).expect("emit");
    println!("P  sparsity(mean±std)  rel_perf(mean±std)  n");
    for row in &rows {
        println!(
            "{:>2}  {:.3}±{:.3}          {:.3}±{:.3}        {}",
            row.p_bits,
            row.sparsity_mean,
            row.sparsity_std,
            row.rel_perf_mean,
            row.rel_perf_std,
            row.n
        );
    }
    // Paper shape: sparsity grows as P shrinks (compare the extremes).
    if rows.len() >= 2 {
        let lo = &rows[0];
        let hi = rows.last().unwrap();
        assert!(
            lo.sparsity_mean >= hi.sparsity_mean,
            "sparsity should grow as P tightens: {} vs {}",
            lo.sparsity_mean,
            hi.sparsity_mean
        );
        println!(
            "fig5 invariant holds (sparsity {:.3} @ P={} >= {:.3} @ P={})",
            lo.sparsity_mean, lo.p_bits, hi.sparsity_mean, hi.p_bits
        );
    }
    println!("wrote results/fig5.csv");
}
