//! Bench + regeneration of paper Fig. 8: associativity breaking under
//! saturating accumulation. Times the permutation study core (scratch
//! buffers reused across permutations) and regenerates results/fig8.csv end
//! to end through the native training backend.

#[path = "harness.rs"]
mod harness;

use a2q::accsim::ReorderScratch;
use a2q::rng::Rng;

fn main() {
    let mut journal = harness::Journal::new();

    // --- microbench: 100-permutation study on a K=784 dot product -----------
    let mut rng = Rng::new(5);
    let x: Vec<i64> = (0..784).map(|_| (rng.uniform() > 0.7) as i64).collect();
    let w: Vec<i64> = (0..784)
        .map(|_| (rng.normal() * 40.0).round().clamp(-128.0, 127.0) as i64)
        .collect();
    let perms = if harness::quick() { 20 } else { 100 };
    let mut scratch = ReorderScratch::new();
    let r = harness::bench(&format!("fig8/reorder_{perms}perm_k784"), 2, 10, || {
        scratch.study(&x, &w, 12, perms, 9)
    });
    let macs = (perms * 784) as u64;
    println!(
        "  ({:.1} M MAC/s through the saturating register)",
        harness::throughput(&r, macs) / 1e6
    );
    journal.add(&r, Some(macs));
    journal.flush();

    // --- end-to-end regeneration (native backend) ----------------------------
    end_to_end();
}

fn end_to_end() {
    use a2q::report::fig8;
    use a2q::runtime::{make_backend, BackendKind};

    let steps = if harness::quick() { 60 } else { 250 };
    let backend = make_backend(BackendKind::Native, "artifacts".as_ref()).expect("backend");
    let t0 = std::time::Instant::now();
    let rep = fig8::run(backend.as_ref(), 12, 100, steps, 128, 0).expect("fig8");
    fig8::emit(&rep, std::path::Path::new("results")).expect("emit");
    let (lo, hi) = rep.inner_acc_spread();
    println!(
        "fig8 end-to-end in {:.1}s: inner acc in [{lo:.4}, {hi:.4}], outer {:.4}, wide {:.4}",
        t0.elapsed().as_secs_f64(),
        rep.outer_acc,
        rep.acc_wide
    );
    // Paper-shape check: the outer-loop (final-only) model underestimates the
    // damage the inner loop actually does.
    assert!(rep.inner_mae_mean() >= rep.outer_mae);
    println!("fig8 invariant holds (inner-loop MAE >= outer-loop MAE)");
}
