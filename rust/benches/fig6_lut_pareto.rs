//! Bench + regeneration of paper Figs. 6 and 7: LUTs-vs-accuracy Pareto
//! frontiers under the four accumulator co-design policies, plus the
//! compute/memory breakdown and the abstract's headline LUT-reduction
//! factor. Consumes sweep records.

#[path = "harness.rs"]
mod harness;

use std::collections::BTreeMap;

use a2q::coordinator::MetricsSink;
use a2q::report::fig67;
use a2q::runtime::ModelManifest;

fn main() {
    let sink = MetricsSink::new("results/runs.jsonl");
    let records = sink.load().expect("sink parse");
    if records.is_empty() {
        println!("no sweep records at results/runs.jsonl; run `a2q sweep` first");
        return;
    }

    let mut geoms = BTreeMap::new();
    let mut models: Vec<String> = records.iter().map(|r| r.config.model.clone()).collect();
    models.sort();
    models.dedup();
    for m in &models {
        let manifest = ModelManifest::load(std::path::Path::new("artifacts"), m).expect("manifest");
        geoms.insert(m.clone(), manifest.geoms().expect("geoms"));
    }

    // Time the full estimate + frontier pass (every record x 4 policies).
    let r = harness::bench("fig6/estimate_all_policies", 2, 10, || {
        fig67::fig6(&records, &geoms)
    });
    println!("  ({} records x 4 policies)", records.len());
    let _ = r;

    let f6 = fig67::fig6(&records, &geoms);
    fig67::emit(&f6, std::path::Path::new("results")).expect("emit");
    for m in &f6 {
        // Paper shape: fixed-32 is never cheaper than the A2Q frontier at
        // comparable accuracy; report the headline factor.
        match fig67::headline_reduction(m, 0.95) {
            Some((red, rel)) => {
                println!(
                    "{:<8} {:.2}x LUT reduction vs fixed-32 at {:.1}% of float perf",
                    m.model,
                    red,
                    rel * 100.0
                );
                assert!(red >= 1.0, "{}: A2Q must not cost more LUTs", m.model);
            }
            None => println!("{:<8} (no point at >=95% of float perf)", m.model),
        }
    }
    println!("wrote results/fig6_*.csv and results/fig7_*.csv");
}
