//! Multi-layer network-forward microbenchmark (EXPERIMENTS.md §Perf):
//! the 26-mode accumulator sweep (wide + wraparound P in 8..=32) over a
//! 3-layer calibrated A2Q QNetwork, run two ways:
//!
//! 1. *per-mode scalar composition* (`network_forward_ref`): one full MAC
//!    traversal of every layer per mode — the reference semantics;
//! 2. *fused network engine* (`network_forward_multi` / `NetworkPlan`): one
//!    thread-scoped batch pass through all layers, modes sharing traversals
//!    until their register models actually diverge.
//!
//! Results are journaled to BENCH_accsim.json and the PERF-NET block of
//! EXPERIMENTS.md §Perf via `a2q::perf`.

#[path = "harness.rs"]
mod harness;

use a2q::accsim::{network_forward_multi, AccMode};
use a2q::model::network_forward_ref;
use a2q::testutil::psweep_network;

/// The wraparound width sweep every figure replays.
const P_SWEEP: std::ops::RangeInclusive<u32> = 8..=32;

fn main() {
    let mut journal = harness::Journal::new();
    let (widths, batch): (Vec<usize>, usize) = if harness::quick() {
        (vec![256, 128, 64, 10], 16)
    } else {
        (vec![784, 256, 128, 10], 64)
    };
    let (net, x) = psweep_network(&widths, batch, 7);
    let modes: Vec<AccMode> = std::iter::once(AccMode::Wide)
        .chain(P_SWEEP.map(|p| AccMode::Wrap { p_bits: p }))
        .collect();
    let macs = (modes.len() * batch * net.macs_per_row()) as u64;
    let iters = if harness::quick() { 2 } else { 5 };

    let rb = harness::bench("accsim/netfwd_scalar_composed", 1, iters, || {
        let mut events = 0u64;
        for mode in &modes {
            let r = network_forward_ref(&net, &x, *mode);
            events += r.layer_stats.iter().map(|s| s.overflow_events).sum::<u64>();
        }
        events
    });
    println!("  ({:.0} M MAC/s)", harness::throughput(&rb, macs) / 1e6);
    journal.add(&rb, Some(macs));

    let rf = harness::bench("accsim/netfwd_fused_network", 1, iters, || {
        network_forward_multi(&net, &x, &modes)
            .iter()
            .flat_map(|r| r.layer_stats.iter())
            .map(|s| s.overflow_events)
            .sum::<u64>()
    });
    println!("  ({:.0} M MAC/s)", harness::throughput(&rf, macs) / 1e6);
    journal.add(&rf, Some(macs));

    let speedup = rb.median.as_secs_f64() / rf.median.as_secs_f64().max(1e-12);
    println!(
        "network forward ({} modes, {} layers {:?}, batch {batch}): fused engine {speedup:.1}x \
         over per-mode scalar composition",
        modes.len(),
        net.depth(),
        widths,
    );
    journal.flush();

    let block = a2q::perf::render_psweep_block(
        &format!(
            "`cargo bench --bench network_forward`{}",
            if harness::quick() { " (quick mode)" } else { "" }
        ),
        &harness::to_record(&rb, Some(macs)),
        &harness::to_record(&rf, Some(macs)),
        &format!("{} modes, {} layers {widths:?}, batch {batch}", modes.len(), net.depth()),
    );
    match a2q::perf::update_experiments_net_block(&block) {
        Ok(true) => println!("EXPERIMENTS.md §Perf PERF-NET block updated"),
        Ok(false) => println!("EXPERIMENTS.md markers absent; skipped PERF-NET update"),
        Err(e) => eprintln!("EXPERIMENTS.md update failed: {e}"),
    }

    // --- the headline A2Q scenario: sweep at/above the net's target width ---
    // Every layer of the fixture satisfies the Eq. 15 cap at P = 16, so a
    // wide + 16..=40 sweep is provably overflow-free at every depth: the
    // partitioned engine keeps all modes fused and runs every layer through
    // the safe-span GEMM.
    let tmodes: Vec<AccMode> = std::iter::once(AccMode::Wide)
        .chain((16..=40).map(|p| AccMode::Wrap { p_bits: p }))
        .collect();
    let tmacs = (tmodes.len() * batch * net.macs_per_row()) as u64;

    let rtb = harness::bench("accsim/netfwd_target_scalar", 1, iters, || {
        let mut events = 0u64;
        for mode in &tmodes {
            let r = network_forward_ref(&net, &x, *mode);
            events += r.layer_stats.iter().map(|s| s.overflow_events).sum::<u64>();
        }
        events
    });
    println!("  ({:.0} M MAC/s)", harness::throughput(&rtb, tmacs) / 1e6);
    journal.add(&rtb, Some(tmacs));

    let rtf = harness::bench("accsim/netfwd_target_gemm", 1, iters, || {
        network_forward_multi(&net, &x, &tmodes)
            .iter()
            .flat_map(|r| r.layer_stats.iter())
            .map(|s| s.overflow_events)
            .sum::<u64>()
    });
    println!("  ({:.0} M MAC/s)", harness::throughput(&rtf, tmacs) / 1e6);
    journal.add(&rtf, Some(tmacs));
    println!(
        "network target-width sweep ({} modes, {} layers {:?}, batch {batch}): \
         safe-span GEMM engine {:.1}x over per-mode scalar composition",
        tmodes.len(),
        net.depth(),
        widths,
        rtb.median.as_secs_f64() / rtf.median.as_secs_f64().max(1e-12)
    );
    journal.flush();
}
