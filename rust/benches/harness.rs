//! Minimal benchmark harness (offline replacement for criterion): warms up,
//! runs timed iterations, reports min/median/mean. Benches are `harness =
//! false` binaries; `cargo bench` runs each `main`.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<40} iters {:>3}  min {:>12?}  median {:>12?}  mean {:>12?}",
            self.name, self.iters, self.min, self.median, self.mean
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs. Returns
/// per-iteration stats; `f`'s return value is black-boxed via `sink`.
#[allow(dead_code)]
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        sink(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        sink(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / iters.max(1) as u32;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        min: times[0],
        median: times[times.len() / 2],
        mean,
    };
    r.print();
    r
}

/// Opaque value sink (prevents the optimizer from deleting the work).
#[allow(dead_code)]
pub fn sink<T>(v: T) {
    let boxed = Box::new(v);
    std::hint::black_box(&boxed);
    drop(boxed);
}

/// Throughput helper: ops/sec at a given per-iteration op count.
#[allow(dead_code)]
pub fn throughput(r: &BenchResult, ops_per_iter: u64) -> f64 {
    ops_per_iter as f64 / r.median.as_secs_f64()
}

/// Scale benchmark sizes down when A2Q_BENCH_QUICK=1 (used by `make test`
/// smoke runs; full `cargo bench` uses paper-scale settings).
#[allow(dead_code)]
pub fn quick() -> bool {
    std::env::var("A2Q_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}
