//! Minimal benchmark harness (offline replacement for criterion): warms up,
//! runs timed iterations, reports min/median/mean. Benches are `harness =
//! false` binaries; `cargo bench` runs each `main`.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<40} iters {:>3}  min {:>12?}  median {:>12?}  mean {:>12?}",
            self.name, self.iters, self.min, self.median, self.mean
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs. Returns
/// per-iteration stats; `f`'s return value is black-boxed via `sink`.
#[allow(dead_code)]
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        sink(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        sink(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / iters.max(1) as u32;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        min: times[0],
        median: times[times.len() / 2],
        mean,
    };
    r.print();
    r
}

/// Opaque value sink (prevents the optimizer from deleting the work).
#[allow(dead_code)]
pub fn sink<T>(v: T) {
    let boxed = Box::new(v);
    std::hint::black_box(&boxed);
    drop(boxed);
}

/// Throughput helper: ops/sec at a given per-iteration op count (a 0ns
/// median — possible for trivial bodies on coarse clocks — must not
/// produce an infinite rate).
#[allow(dead_code)]
pub fn throughput(r: &BenchResult, ops_per_iter: u64) -> f64 {
    ops_per_iter as f64 / r.median.as_secs_f64().max(1e-12)
}

/// Scale benchmark sizes down when A2Q_BENCH_QUICK=1 (used by `make test`
/// smoke runs; full `cargo bench` uses paper-scale settings).
#[allow(dead_code)]
pub fn quick() -> bool {
    std::env::var("A2Q_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Convert a timed result into the journal record shape (`{name, median
/// ns/iter, MAC/s}`) — the single definition the journal and the
/// EXPERIMENTS.md block renderers all go through.
#[allow(dead_code)]
pub fn to_record(r: &BenchResult, macs_per_iter: Option<u64>) -> a2q::perf::BenchRecord {
    to_record_sparse(r, macs_per_iter, None)
}

/// Like [`to_record`] but stamps the measured weight sparsity of the bench's
/// layer — kernel-dispatch benches use this so the journal shows what
/// density each scalar/SIMD/sparse row ran against.
#[allow(dead_code)]
pub fn to_record_sparse(
    r: &BenchResult,
    macs_per_iter: Option<u64>,
    sparsity: Option<f64>,
) -> a2q::perf::BenchRecord {
    a2q::perf::BenchRecord {
        name: r.name.clone(),
        ns_per_iter: r.median.as_nanos() as f64,
        mac_per_s: macs_per_iter.map(|m| throughput(r, m)),
        sparsity,
    }
}

/// Machine-readable journal: collects results during a bench run, then
/// merges them into `BENCH_accsim.json` at the repo root (name, ns/iter,
/// MAC/s) so the perf trajectory is tracked across PRs alongside stdout.
#[allow(dead_code)]
#[derive(Default)]
pub struct Journal {
    records: Vec<a2q::perf::BenchRecord>,
}

#[allow(dead_code)]
impl Journal {
    pub fn new() -> Journal {
        Journal::default()
    }

    /// Record a result; pass the per-iteration MAC count for MAC/s.
    pub fn add(&mut self, r: &BenchResult, macs_per_iter: Option<u64>) {
        self.records.push(to_record(r, macs_per_iter));
    }

    /// Record a result with the layer's measured weight sparsity attached.
    pub fn add_sparse(
        &mut self,
        r: &BenchResult,
        macs_per_iter: Option<u64>,
        sparsity: Option<f64>,
    ) {
        self.records.push(to_record_sparse(r, macs_per_iter, sparsity));
    }

    /// Merge into BENCH_accsim.json; prints where the journal went.
    pub fn flush(&self) {
        if self.records.is_empty() {
            return;
        }
        match a2q::perf::record_benches(&self.records) {
            Ok(path) => println!("perf journal: {} entries -> {}", self.records.len(), path.display()),
            Err(e) => eprintln!("perf journal write failed: {e}"),
        }
    }
}
