//! Native `train_step` throughput (EXPERIMENTS.md §Perf): rows/s through
//! the pure-Rust backend's forward + STE backward + SGD update on the
//! default multi-layer MLP manifest (`mlp3`, 784 -> 64 -> 16 -> 2), at the
//! M4N4 and M8N8 grid points.
//!
//! Results are journaled to BENCH_accsim.json (`native/trainstep_*`) via
//! `a2q::perf`; MAC/s counts forward + both backward GEMM passes (3x the
//! forward MACs), rows/s is printed alongside.

#[path = "harness.rs"]
mod harness;

use a2q::datasets::{self, Split};
use a2q::runtime::{NativeBackend, TrainBackend};

fn main() {
    let mut journal = harness::Journal::new();
    let backend = NativeBackend::new("artifacts");
    let manifest = backend.manifest("mlp3").expect("native registry manifest");
    let bs = manifest.batch_size;
    let ds = datasets::by_name("synth_mnist", 512, 64, 0).unwrap();
    let idx: Vec<usize> = (0..bs).collect();
    let batch = ds.gather(Split::Train, &idx);
    let macs_fwd: usize = manifest.qlayers.iter().map(|q| q.c_out * q.k).sum();
    let iters = if harness::quick() { 5 } else { 20 };
    let steps_per_iter = if harness::quick() { 2 } else { 5 };

    for (label, bits) in [("m4n4", (4u32, 4u32, 14u32)), ("m8n8", (8u32, 8u32, 20u32))] {
        let mut state = backend.init(&manifest, 0.0).expect("init");
        // warm + sanity: the loop must stay finite at this grid point
        let warm = backend
            .train_step(&manifest, "a2q", &mut state, &batch.x, &batch.y, bits, 0.05)
            .expect("warm step");
        assert!(warm.is_finite());
        let r = harness::bench(&format!("native/trainstep_{label}"), 1, iters, || {
            let mut last = 0.0f32;
            for _ in 0..steps_per_iter {
                last = backend
                    .train_step(&manifest, "a2q", &mut state, &batch.x, &batch.y, bits, 0.05)
                    .expect("train step");
            }
            last
        });
        let macs = (steps_per_iter * bs * macs_fwd * 3) as u64;
        let rows_per_s = (steps_per_iter * bs) as f64 / r.median.as_secs_f64().max(1e-12);
        println!(
            "  ({rows_per_s:.0} rows/s, {:.1} M MAC/s incl. backward)",
            harness::throughput(&r, macs) / 1e6
        );
        journal.add(&r, Some(macs));
    }
    journal.flush();
}
