//! Native `train_step` throughput (EXPERIMENTS.md §Perf-Train): rows/s
//! through the pure-Rust backend's forward + STE backward + optimizer
//! update, measured at all three compute paths so the speedup of the
//! blocked GEMM core over the scalar reference is a tracked number:
//!
//! * `*_scalar`  — the scalar triple-loop reference (`ComputePath::Scalar`);
//! * `*_blocked` — the packed blocked GEMM core pinned to one thread;
//! * `*_threads` — the blocked core with the batch fanned over the default
//!   worker heuristic.
//!
//! Runs both registry shapes: `mlp` (the Fig. 2 single layer, 784 -> 2 at
//! M8N1P16) and `mlp3` (784 -> 64 -> 16 -> 2 at M4N4P14). Results are
//! journaled to BENCH_accsim.json (`native/trainstep_*`) via `a2q::perf`;
//! CI seeds the journal with this bench and asserts blocked >= scalar
//! through `a2q perfcheck`. MAC/s counts forward + both backward GEMM
//! passes (3x the forward MACs).

#[path = "harness.rs"]
mod harness;

use a2q::datasets::{self, Split};
use a2q::linalg::KernelPath;
use a2q::perf::TrainRow;
use a2q::runtime::{ComputePath, NativeBackend, TrainBackend};

fn main() {
    let mut journal = harness::Journal::new();
    let iters = if harness::quick() { 5 } else { 20 };
    let steps_per_iter = if harness::quick() { 2 } else { 5 };
    let mut groups: Vec<(&str, Vec<TrainRow>)> = Vec::new();

    for (model, shape, bits) in [
        ("mlp", "mlp 784->2 @ M8N1P16", (8u32, 1u32, 16u32)),
        ("mlp3", "mlp3 784->64->16->2 @ M4N4P14", (4u32, 4u32, 14u32)),
    ] {
        let manifest = NativeBackend::new("artifacts")
            .manifest(model)
            .expect("native registry manifest");
        let bs = manifest.batch_size;
        let ds = datasets::by_name("synth_mnist", 512, 64, 0).unwrap();
        let idx: Vec<usize> = (0..bs).collect();
        let batch = ds.gather(Split::Train, &idx);
        let macs_fwd: usize = manifest.qlayers.iter().map(|q| q.c_out * q.k).sum();
        let macs = (steps_per_iter * bs * macs_fwd * 3) as u64;
        let mut rows = Vec::new();

        for (path_label, backend) in [
            ("scalar", NativeBackend::new("artifacts").with_compute(ComputePath::Scalar)),
            ("blocked", NativeBackend::new("artifacts").with_threads(1)),
            ("threads", NativeBackend::new("artifacts")),
        ] {
            let mut state = backend.init(&manifest, 0.0).expect("init");
            // warm + sanity: the loop must stay finite at this grid point
            let warm = backend
                .train_step(&manifest, "a2q", &mut state, &batch.x, &batch.y, bits, 0.05)
                .expect("warm step");
            assert!(warm.is_finite());
            let name = format!("native/trainstep_{model}_{path_label}");
            let r = harness::bench(&name, 1, iters, || {
                let mut last = 0.0f32;
                for _ in 0..steps_per_iter {
                    last = backend
                        .train_step(&manifest, "a2q", &mut state, &batch.x, &batch.y, bits, 0.05)
                        .expect("train step");
                }
                last
            });
            let rows_per_s = (steps_per_iter * bs) as f64 / r.median.as_secs_f64().max(1e-12);
            println!(
                "  ({rows_per_s:.0} rows/s, {:.1} M MAC/s incl. backward)",
                harness::throughput(&r, macs) / 1e6
            );
            journal.add(&r, Some(macs));
            rows.push(TrainRow { name, ns_per_iter: r.median.as_nanos() as f64, rows_per_s });
        }
        if let (Some(s), Some(b)) = (rows.first(), rows.get(1)) {
            println!(
                "  blocked speedup over scalar: {:.2}x",
                s.ns_per_iter / b.ns_per_iter.max(1.0)
            );
        }
        groups.push((shape, rows));
    }

    // --- mlp3 under each forced GEMM kernel path -----------------------------
    // Same step as the blocked row above, threads pinned to 1 and the
    // kernel dispatch forced, so the three rows isolate the microkernel
    // (scalar vs SIMD vs sparse panels). The journal rows carry the
    // trained model's measured weight sparsity — the A2Q l1 budget is what
    // makes the sparse path worth having.
    {
        let (model, bits) = ("mlp3", (4u32, 4u32, 14u32));
        let manifest = NativeBackend::new("artifacts").manifest(model).expect("manifest");
        let bs = manifest.batch_size;
        let ds = datasets::by_name("synth_mnist", 512, 64, 0).unwrap();
        let idx: Vec<usize> = (0..bs).collect();
        let batch = ds.gather(Split::Train, &idx);
        let macs_fwd: usize = manifest.qlayers.iter().map(|q| q.c_out * q.k).sum();
        let macs = (steps_per_iter * bs * macs_fwd * 3) as u64;

        // measure the sparsity the quantizer settles into after a few steps
        let probe = NativeBackend::new("artifacts").with_threads(1);
        let mut pstate = probe.init(&manifest, 0.0).expect("init");
        for _ in 0..5 {
            probe
                .train_step(&manifest, "a2q", &mut pstate, &batch.x, &batch.y, bits, 0.05)
                .expect("probe step");
        }
        let (mut zeros, mut total) = (0.0f64, 0.0f64);
        for layer in probe.export(&manifest, "a2q", &pstate, bits).expect("export") {
            let q = layer.to_qtensor();
            let n = (q.c_out * q.k) as f64;
            zeros += q.sparsity() * n;
            total += n;
        }
        let sparsity = if total > 0.0 { zeros / total } else { 0.0 };

        let mut rows = Vec::new();
        for (label, path) in [
            ("kscalar", KernelPath::Scalar),
            ("ksimd", KernelPath::Simd),
            ("ksparse", KernelPath::SparseSimd),
        ] {
            let backend = NativeBackend::new("artifacts").with_threads(1).with_kernel(path);
            let mut state = backend.init(&manifest, 0.0).expect("init");
            let warm = backend
                .train_step(&manifest, "a2q", &mut state, &batch.x, &batch.y, bits, 0.05)
                .expect("warm step");
            assert!(warm.is_finite());
            let name = format!("native/trainstep_{model}_{label}");
            let r = harness::bench(&name, 1, iters, || {
                let mut last = 0.0f32;
                for _ in 0..steps_per_iter {
                    last = backend
                        .train_step(&manifest, "a2q", &mut state, &batch.x, &batch.y, bits, 0.05)
                        .expect("train step");
                }
                last
            });
            let rows_per_s = (steps_per_iter * bs) as f64 / r.median.as_secs_f64().max(1e-12);
            println!(
                "  ({rows_per_s:.0} rows/s, {:.1} M MAC/s incl. backward, weight sparsity {sparsity:.3})",
                harness::throughput(&r, macs) / 1e6
            );
            journal.add_sparse(&r, Some(macs), Some(sparsity));
            rows.push(TrainRow { name, ns_per_iter: r.median.as_nanos() as f64, rows_per_s });
        }
        groups.push(("mlp3 forced kernel @ M4N4P14, 1 thread", rows));
    }

    journal.flush();
    let block = a2q::perf::render_train_block(
        &format!(
            "`cargo bench --bench train_step` (release{})",
            if harness::quick() { ", quick" } else { "" }
        ),
        &groups,
    );
    match a2q::perf::update_experiments_train_block(&block) {
        Ok(true) => println!("EXPERIMENTS.md §Perf-Train block updated"),
        Ok(false) => println!("EXPERIMENTS.md markers not found; train block not updated"),
        Err(e) => eprintln!("EXPERIMENTS.md not writable here ({e}); train block not updated"),
    }
}
