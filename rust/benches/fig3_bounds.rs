//! Bench + regeneration of paper Fig. 3: the accumulator bound comparison.
//! Pure computation (no artifacts needed). Emits results/fig3.csv and times
//! the 1000-draw sampling study.

#[path = "harness.rs"]
mod harness;

use a2q::report::fig3;

fn main() {
    let draws = if harness::quick() { 50 } else { 1000 };
    let ks: Vec<usize> = (5..=14).map(|e| 1usize << e).collect();
    let bits = [4u32, 5, 6, 7, 8];

    let r = harness::bench("fig3/bounds_1000_draws", 1, 5, || {
        fig3::run(&ks, &bits, draws, 0)
    });
    println!(
        "  ({} grid cells x {draws} draws -> {:.1} Mdraws/s)",
        ks.len() * bits.len(),
        harness::throughput(&r, (ks.len() * bits.len() * draws) as u64) / 1e6
    );

    // Regenerate the figure data alongside the timing.
    let rows = fig3::run(&ks, &bits, draws, 0);
    fig3::emit(&rows, std::path::Path::new("results")).expect("emit fig3");
    println!("wrote results/fig3.csv ({} rows)", rows.len());

    // Shape assertions that mirror the paper's plot: weight bound strictly
    // tighter than the data-type bound, both increasing in K.
    for r in &rows {
        assert!(r.weight_bound_max <= r.data_type_bound + 1e-9);
    }
    println!("fig3 invariants hold (weight bound <= data-type bound everywhere)");
}
