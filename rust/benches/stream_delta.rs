//! Streaming sparse-delta throughput (EXPERIMENTS.md §Perf-Stream): rows/s
//! through the NNUE-style incremental sessions versus a full recompute on
//! every tick, at matched delta streams:
//!
//! * `accsim/stream_full_forward` — apply each tick to a plain input matrix
//!   and run the batch engine from scratch (the pre-stream baseline);
//! * `accsim/stream_delta_d05`    — the incremental session at 5% delta
//!   density (the steady-state streaming regime; CI gates this row at or
//!   ahead of the full forward via `a2q perfcheck`);
//! * `accsim/stream_delta_d25`    — 25% density, approaching the
//!   refresh-threshold crossover where incremental stops paying;
//! * `accsim/stream_net_*`        — the same pair through a whole
//!   [`NetworkPlan`] (maintained layer-0 accumulators, deeper layers
//!   recomputed).
//!
//! Both sides of each pair consume *identically seeded* delta streams
//! generated inside the timed region (generation cost is paid equally), so
//! after the benches the final states must be bit-identical — outputs and
//! overflow counters — and this binary asserts exactly that. Results are
//! journaled to BENCH_accsim.json via `a2q::perf`.

#[path = "harness.rs"]
mod harness;

use a2q::accsim::{AccMode, IntMatrix, LayerPlan, LayerStreamSession, NetworkPlan, StreamSession};
use a2q::perf::TrainRow;
use a2q::rng::Rng;
use a2q::testutil::{apply_deltas, psweep_constrained_layer, psweep_network, stream_delta_tick};

fn main() {
    let mut journal = harness::Journal::new();
    let quick = harness::quick();
    let iters = if quick { 5 } else { 15 };
    let ticks = if quick { 3 } else { 10 };
    let mut groups: Vec<(&str, Vec<TrainRow>)> = Vec::new();

    // --- single A2Q-constrained layer ------------------------------------
    // P = 14 with 8-bit inputs squeezes the l1 budget until most codes are
    // zero (same regime as the kernel-dispatch bench): every channel is
    // provably safe, so the full forward is pure safe-span GEMM — the
    // strongest baseline the incremental path has to beat.
    let (c_out, k, batch) = if quick { (32, 64, 16) } else { (128, 256, 64) };
    let (p, n) = (14u32, 8u32);
    let w = psweep_constrained_layer(c_out, k, p, n, 7);
    let sparsity = w.sparsity();
    assert!(sparsity >= 0.70, "stream fixture must be >= 70% sparse, got {sparsity:.3}");
    let modes = [AccMode::Wide, AccMode::Wrap { p_bits: p }];
    let plan = LayerPlan::new(&w, &modes);
    let x_scale = 0.05f32;
    let mut xrng = Rng::new(7 ^ 0x57AE);
    let x0 = IntMatrix::from_flat(
        batch,
        k,
        (0..batch * k).map(|_| xrng.below(1usize << n) as i64).collect(),
    );
    let rows_per_iter = (ticks * batch) as f64;
    // Nominal (full-recompute-equivalent) MACs served per iteration: both
    // rows deliver the same forwards, so the same denominator keeps the
    // journal's MAC/s comparable.
    let macs = (ticks * batch * c_out * k) as u64;
    let per_row_d05 = ((k as f64) * 0.05).round().max(1.0) as usize;
    let per_row_d25 = ((k as f64) * 0.25).round().max(1.0) as usize;
    let mut rows = Vec::new();

    // Full-forward baseline over the d=5% stream (seed shared with the
    // incremental row below so final states can be compared bitwise).
    let mut frng = Rng::new(0xD5);
    let mut xf = x0.clone();
    let rfull = harness::bench("accsim/stream_full_forward", 1, iters, || {
        let mut events = 0u64;
        for _ in 0..ticks {
            let tick = stream_delta_tick(&xf, per_row_d05, n, &mut frng);
            apply_deltas(&mut xf, &tick);
            events += plan.execute_threads(&xf, x_scale, 1)[1].stats.overflow_events;
        }
        events
    });
    let full_rows_s = rows_per_iter / rfull.median.as_secs_f64().max(1e-12);
    println!("  ({full_rows_s:.0} rows/s, weight sparsity {sparsity:.3})");
    journal.add_sparse(&rfull, Some(macs), Some(sparsity));
    rows.push(TrainRow {
        name: rfull.name.clone(),
        ns_per_iter: rfull.median.as_nanos() as f64,
        rows_per_s: full_rows_s,
    });

    let mut srng = Rng::new(0xD5);
    let mut session = LayerStreamSession::new(&plan, x0.clone(), x_scale);
    let rinc = harness::bench("accsim/stream_delta_d05", 1, iters, || {
        let mut events = 0u64;
        for _ in 0..ticks {
            let tick = stream_delta_tick(session.x(), per_row_d05, n, &mut srng);
            session.apply(&tick).unwrap();
            events += session.forward_threads(1)[1].stats.overflow_events;
        }
        events
    });
    let inc_rows_s = rows_per_iter / rinc.median.as_secs_f64().max(1e-12);
    println!(
        "  ({inc_rows_s:.0} rows/s, {per_row_d05} deltas/row, {} rows refreshed)",
        session.refreshed_rows()
    );
    journal.add_sparse(&rinc, Some(macs), Some(sparsity));
    rows.push(TrainRow {
        name: rinc.name.clone(),
        ns_per_iter: rinc.median.as_nanos() as f64,
        rows_per_s: inc_rows_s,
    });

    // Identical streams => identical final state, bit for bit.
    assert_eq!(session.x(), &xf, "incremental input state diverged from the mirror");
    let got = session.forward_threads(1);
    let want = plan.execute_threads(&xf, x_scale, 1);
    for (g, b) in got.iter().zip(&want) {
        assert_eq!(g.out.data(), b.out.data());
        assert_eq!(g.out_wide.data(), b.out_wide.data());
        assert_eq!(g.stats.overflow_events, b.stats.overflow_events);
        assert_eq!(g.stats.abs_err_sum, b.stats.abs_err_sum);
        assert_eq!(g.stats.outputs, b.stats.outputs);
    }
    println!("  bit-identity verified against the full recompute");

    // 25% density: approaching the crossover where the refresh fallback
    // takes over (still bit-identical, journaled for the trend line).
    let mut drng = Rng::new(0xD25);
    let mut dsession = LayerStreamSession::new(&plan, x0.clone(), x_scale);
    let rd25 = harness::bench("accsim/stream_delta_d25", 1, iters, || {
        let mut events = 0u64;
        for _ in 0..ticks {
            let tick = stream_delta_tick(dsession.x(), per_row_d25, n, &mut drng);
            dsession.apply(&tick).unwrap();
            events += dsession.forward_threads(1)[1].stats.overflow_events;
        }
        events
    });
    let d25_rows_s = rows_per_iter / rd25.median.as_secs_f64().max(1e-12);
    println!(
        "  ({d25_rows_s:.0} rows/s, {per_row_d25} deltas/row, {} rows refreshed)",
        dsession.refreshed_rows()
    );
    journal.add_sparse(&rd25, Some(macs), Some(sparsity));
    rows.push(TrainRow {
        name: rd25.name.clone(),
        ns_per_iter: rd25.median.as_nanos() as f64,
        rows_per_s: d25_rows_s,
    });
    println!(
        "stream layer ({batch} rows x {c_out}x{k}, {ticks} ticks/iter): incremental d=5% \
         {:.2}x over full forward",
        rfull.median.as_secs_f64() / rinc.median.as_secs_f64().max(1e-12)
    );
    let layer_label = if quick {
        "layer 32x64 @ P14N8, 1 thread"
    } else {
        "layer 128x256 @ P14N8, 1 thread"
    };
    groups.push((layer_label, rows));
    journal.flush();

    // --- whole network: maintained layer-0 accumulators -------------------
    let widths: Vec<usize> = if quick {
        vec![64, 32, 16, 4]
    } else {
        vec![256, 128, 64, 10]
    };
    let net_batch = if quick { 16 } else { 64 };
    let (net, xn0) = psweep_network(&widths, net_batch, 11);
    let net_n_bits = 4u32;
    let nmodes = [AccMode::Wide, AccMode::Wrap { p_bits: 16 }];
    let nplan = NetworkPlan::new(&net, &nmodes);
    let net_macs_row: usize = widths.windows(2).map(|pair| pair[0] * pair[1]).sum();
    let nmacs = (ticks * net_batch * net_macs_row) as u64;
    let net_rows_iter = (ticks * net_batch) as f64;
    let net_per_row = ((widths[0] as f64) * 0.05).round().max(1.0) as usize;
    let mut nrows = Vec::new();

    let mut nfrng = Rng::new(0xA5);
    let mut xnf = xn0.clone();
    let rnfull = harness::bench("accsim/stream_net_full_forward", 1, iters, || {
        let mut events = 0u64;
        for _ in 0..ticks {
            let tick = stream_delta_tick(&xnf, net_per_row, net_n_bits, &mut nfrng);
            apply_deltas(&mut xnf, &tick);
            let wrapped = &nplan.execute_threads(&xnf, 1)[1];
            events += wrapped.layer_stats.iter().map(|s| s.overflow_events).sum::<u64>();
        }
        events
    });
    let nfull_rows_s = net_rows_iter / rnfull.median.as_secs_f64().max(1e-12);
    println!("  ({nfull_rows_s:.0} rows/s)");
    journal.add(&rnfull, Some(nmacs));
    nrows.push(TrainRow {
        name: rnfull.name.clone(),
        ns_per_iter: rnfull.median.as_nanos() as f64,
        rows_per_s: nfull_rows_s,
    });

    let mut nsrng = Rng::new(0xA5);
    let mut nsession = StreamSession::new(&nplan, xn0.clone());
    let rninc = harness::bench("accsim/stream_net_delta_d05", 1, iters, || {
        let mut events = 0u64;
        for _ in 0..ticks {
            let tick = stream_delta_tick(nsession.x(), net_per_row, net_n_bits, &mut nsrng);
            nsession.apply(&tick).unwrap();
            let wrapped = &nsession.forward_threads(1)[1];
            events += wrapped.layer_stats.iter().map(|s| s.overflow_events).sum::<u64>();
        }
        events
    });
    let ninc_rows_s = net_rows_iter / rninc.median.as_secs_f64().max(1e-12);
    println!(
        "  ({ninc_rows_s:.0} rows/s, {net_per_row} deltas/row, {} rows refreshed)",
        nsession.refreshed_rows()
    );
    journal.add(&rninc, Some(nmacs));
    nrows.push(TrainRow {
        name: rninc.name.clone(),
        ns_per_iter: rninc.median.as_nanos() as f64,
        rows_per_s: ninc_rows_s,
    });

    assert_eq!(nsession.x(), &xnf, "network stream state diverged from the mirror");
    let ngot = nsession.forward_threads(1);
    let nwant = nplan.execute_threads(&xnf, 1);
    for (g, b) in ngot.iter().zip(&nwant) {
        assert_eq!(g.out.data(), b.out.data());
        assert_eq!(g.out_wide.data(), b.out_wide.data());
        for (gs, bs) in g.layer_stats.iter().zip(&b.layer_stats) {
            assert_eq!(gs.overflow_events, bs.overflow_events);
            assert_eq!(gs.abs_err_sum, bs.abs_err_sum);
            assert_eq!(gs.outputs, bs.outputs);
        }
    }
    println!("  network bit-identity verified against the full recompute");
    println!(
        "stream net ({net_batch} rows x {widths:?}, {ticks} ticks/iter): incremental d=5% \
         {:.2}x over full forward",
        rnfull.median.as_secs_f64() / rninc.median.as_secs_f64().max(1e-12)
    );
    let net_label = if quick {
        "net 64-32-16-4 @ P16N4, 1 thread"
    } else {
        "net 256-128-64-10 @ P16N4, 1 thread"
    };
    groups.push((net_label, nrows));
    journal.flush();

    // Refresh the auto-recorded §Perf-Stream block of EXPERIMENTS.md.
    let block = a2q::perf::render_stream_block(
        &format!(
            "`cargo bench --bench stream_delta` (release{})",
            if quick { ", quick" } else { "" }
        ),
        &groups,
    );
    match a2q::perf::update_experiments_stream_block(&block) {
        Ok(true) => println!("EXPERIMENTS.md §Perf-Stream block updated"),
        Ok(false) => println!("EXPERIMENTS.md markers not found; stream block not updated"),
        Err(e) => eprintln!("EXPERIMENTS.md update failed: {e}"),
    }
}
