//! Accumulator-constrained optimization (paper §5.2 in miniature).
//!
//! Sweeps the target accumulator width P for one model and reports the
//! accuracy / sparsity trade-off of A2Q against the baseline-QAT heuristic
//! (whose minimum safe P is pinned at its data-type bound) — the Fig. 4/5
//! story on a single model.
//!
//! Run: `cargo run --release --example accumulator_sweep [model] [steps]`

use a2q::config::RunConfig;
use a2q::coordinator::Trainer;
use a2q::quant::bounds::{data_type_bound, DotShape};
use a2q::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "mlp".to_string());
    let steps: u64 = std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(300);
    let engine = Engine::new("artifacts")?;
    let manifest = engine.manifest(&model)?;

    // mlp is the paper's (M=8, N=1) motivating setup; conv models use M=N=6.
    let (m, n) = if model == "mlp" { (8, 1) } else { (6, 6) };
    let dt_bound = data_type_bound(DotShape {
        k: manifest.largest_k,
        m_bits: m,
        n_bits: n,
        x_signed: false,
    })
    .min(32);
    println!("{model}: K*={}, data-type bound P >= {dt_bound}", manifest.largest_k);

    // Baseline QAT: accumulator-oblivious; its safe deployment P is dt_bound.
    let mut qat = RunConfig::new(&model, "qat", m, n, 32, steps);
    if model == "mlp" {
        qat.lr = Some(0.05);
    }
    let trainer = Trainer::new(&engine, &qat)?;
    let qat_out = trainer.run(&qat)?;
    println!(
        "\n{:<22} {:>4} {:>9} {:>9}",
        "scheme", "P", "perf", "sparsity"
    );
    println!(
        "{:<22} {:>4} {:>9.4} {:>9.3}   (P pinned at its bound)",
        "qat (heuristic)", dt_bound, qat_out.perf, qat_out.sparsity
    );

    // A2Q: P is a free design variable.
    for off in [0u32, 2, 4, 6, 8, 10] {
        let p = dt_bound.saturating_sub(off).max(4);
        let mut cfg = RunConfig::new(&model, "a2q", m, n, p, steps);
        if model == "mlp" {
            cfg.lr = Some(0.05);
        }
        let out = trainer.run(&cfg)?;
        anyhow::ensure!(out.guarantee_ok, "Eq. 15 violated at P={p}");
        println!(
            "{:<22} {:>4} {:>9.4} {:>9.3}",
            format!("a2q (target P={p})"),
            p,
            out.perf,
            out.sparsity
        );
    }
    println!("\nA2Q reaches accumulator widths the data-type heuristic cannot (paper Fig. 4),");
    println!("and sparsity grows as P tightens (paper Fig. 5).");
    Ok(())
}
