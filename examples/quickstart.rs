//! Quickstart: the 60-second tour of the public API.
//!
//! 1. Compute the paper's accumulator bounds for a layer shape.
//! 2. Train the 1-layer binary-MNIST QNN with A2Q at a 14-bit accumulator
//!    target, fully from Rust via the AOT artifacts.
//! 3. Export the integer weights and *prove* overflow is impossible with the
//!    bit-exact accumulation simulator.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use a2q::accsim::matmul::quantize_inputs;
use a2q::accsim::{qlinear_forward, AccMode};
use a2q::config::RunConfig;
use a2q::coordinator::Trainer;
use a2q::datasets::Split;
use a2q::quant::bounds::{data_type_bound, weight_bound, DotShape};
use a2q::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // --- 1. bounds (paper Sec. 3) ------------------------------------------
    let shape = DotShape { k: 784, m_bits: 8, n_bits: 1, x_signed: false };
    println!("binary-MNIST layer: K=784, M=8, N=1");
    println!("  data-type bound (Eq. 8):  P >= {}", data_type_bound(shape));
    println!(
        "  weight bound at ||w||_1=4000 (Eq. 12): P >= {}",
        weight_bound(4000.0, 1, false)
    );

    // --- 2. train with A2Q at P = 14 ---------------------------------------
    let target_p = 14;
    let engine = Engine::new("artifacts")?;
    let mut cfg = RunConfig::new("mlp", "a2q", 8, 1, target_p, 300);
    cfg.lr = Some(0.05);
    let trainer = Trainer::new(&engine, &cfg)?;
    let outcome = trainer.run(&cfg)?;
    println!(
        "\ntrained mlp with A2Q @ P={target_p}: test acc {:.3}, weight sparsity {:.2}",
        outcome.perf, outcome.sparsity
    );
    assert!(outcome.guarantee_ok, "Eq. 15 audit must pass");

    // --- 3. prove overflow avoidance with the bit-exact simulator ----------
    let layer = outcome.exported.as_ref().unwrap()[0].to_qtensor();
    println!("exported integer weights: max ||w||_1 = {}", layer.max_l1());
    let idx: Vec<usize> = (0..256).collect();
    let batch = trainer.dataset.gather(Split::Test, &idx);
    let x_int = quantize_inputs(&batch.x, 1.0, 1, false);
    let sim = qlinear_forward(&x_int, 1.0, &layer, AccMode::Wrap { p_bits: target_p });
    println!(
        "simulated {} dot products ({} MACs) in a {target_p}-bit wraparound register: {} overflows",
        sim.stats.dots, sim.stats.macs, sim.stats.overflow_events
    );
    assert_eq!(sim.stats.overflow_events, 0, "A2Q guarantees this is zero");
    println!("guaranteed overflow avoidance: VERIFIED");
    Ok(())
}
