//! Overflow mechanics demo (paper Fig. 2 + Fig. 8 intuition, no training):
//! what actually happens inside a P-bit accumulator register.
//!
//! Entirely self-contained (uses the accsim substrate on synthetic integer
//! vectors), so it runs without artifacts.
//!
//! Run: `cargo run --release --example overflow_demo`

use a2q::accsim::reorder::reorder_study;
use a2q::accsim::{dot_accumulate, AccMode};
use a2q::quant::a2q::{a2q_quantize_row, l1_cap, row_satisfies_cap};
use a2q::quant::bounds::{data_type_bound, weight_bound, DotShape};
use a2q::rng::Rng;

fn main() {
    let k = 784;
    let (m_bits, n_bits) = (8u32, 1u32);
    let mut rng = Rng::new(7);

    // Random 8-bit weights and 1-bit inputs, like the Fig. 2 model.
    let w: Vec<i64> = (0..k).map(|_| (rng.normal() * 40.0).round().clamp(-128.0, 127.0) as i64).collect();
    let x: Vec<i64> = (0..k).map(|_| (rng.uniform() > 0.7) as i64).collect();
    let shape = DotShape { k, m_bits, n_bits, x_signed: false };
    let l1: i64 = w.iter().map(|v| v.abs()).sum();

    println!("K={k}, M={m_bits}, N={n_bits}: data-type bound P >= {}", data_type_bound(shape));
    println!("this draw: ||w||_1 = {l1} -> weight bound P >= {}\n", weight_bound(l1 as f64, n_bits, false));

    println!("{:>4} {:>12} {:>6} {:>12} {:>6}", "P", "wrap", "ovf", "saturate", "ovf");
    let wide = dot_accumulate(&x, &w, AccMode::Wide).value;
    for p in [20, 16, 14, 12, 10, 8] {
        let wr = dot_accumulate(&x, &w, AccMode::Wrap { p_bits: p });
        let sat = dot_accumulate(&x, &w, AccMode::Saturate { p_bits: p });
        println!("{p:>4} {:>12} {:>6} {:>12} {:>6}", wr.value, wr.overflows, sat.value, sat.overflows);
    }
    println!("(wide-register truth: {wide})\n");

    // Associativity: saturation makes the answer order-dependent.
    let study = reorder_study(&x, &w, 12, 100, 3);
    println!(
        "saturating @ P=12 over 100 random MAC orders: {} distinct results (wide register: always {})",
        study.distinct_inner(),
        study.wide_value
    );

    // A2Q the same weights: quantize with the norm constrained for P=12.
    let v: Vec<f32> = w.iter().map(|v| *v as f32).collect();
    let (w_a2q, _) = a2q_quantize_row(&v, 0.0, 30.0, m_bits, n_bits, 12, false);
    assert!(row_satisfies_cap(&w_a2q, 12, n_bits, false));
    let wq: Vec<i64> = w_a2q.iter().map(|v| *v as i64).collect();
    let r = dot_accumulate(&x, &wq, AccMode::Wrap { p_bits: 12 });
    println!(
        "\nafter A2Q re-quantization for P=12 (l1 cap {:.1}): ||w||_1 = {}, overflows = {}",
        l1_cap(12, n_bits, false),
        wq.iter().map(|v| v.abs()).sum::<i64>(),
        r.overflows
    );
    assert_eq!(r.overflows, 0);
    println!("overflow impossible, order-independent, associativity restored.");
}
