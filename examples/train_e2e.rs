//! End-to-end training driver (the DESIGN.md §6 validation example).
//!
//! Trains the MobileNet-style CNN with A2Q at (M=6, N=6, P=16) for several
//! hundred steps on synthetic CIFAR, entirely from Rust against the AOT
//! train-step artifact, then:
//!   * logs the loss curve (printed + results/train_e2e_loss.csv),
//!   * evaluates test accuracy and compares against the float baseline,
//!   * exports the deployment weights and audits the Eq. 15 guarantee on
//!     every constrained layer,
//!   * checkpoints the final state and verifies a bit-exact restore.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e [steps]`

use a2q::config::RunConfig;
use a2q::coordinator::checkpoint::Checkpoint;
use a2q::coordinator::Trainer;
use a2q::quant::a2q::l1_cap;
use a2q::report::write_csv;
use a2q::runtime::{Engine, TrainBackend};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(400);
    let engine = Engine::new("artifacts")?;

    let mut cfg = RunConfig::new("cnn", "a2q", 6, 6, 16, steps);
    cfg.n_train = 2048;
    cfg.n_test = 512;
    // Cool the schedule for the longer run: the model's default 5e-2 SGD is
    // tuned for ~150-step sweeps and can destabilize once converged.
    cfg.lr = Some(0.02);
    cfg.lr_decay_every = 100;
    let trainer = Trainer::new(&engine, &cfg)?;
    println!(
        "training {} (batch {}, {} train / {} test samples) with A2Q @ (M=6, N=6, P=16)",
        cfg.model, trainer.manifest.batch_size, cfg.n_train, cfg.n_test
    );

    let t0 = std::time::Instant::now();
    let outcome = trainer.run(&cfg)?;
    println!(
        "trained {steps} steps in {:.1}s ({:.1} ms/step)",
        t0.elapsed().as_secs_f64(),
        1e3 * outcome.train_secs / steps as f64
    );

    // Loss curve: print a coarse view, persist the full curve.
    let hist = &outcome.loss_history;
    for (step, loss) in hist.iter().step_by((hist.len() / 12).max(1)) {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    let rows: Vec<Vec<String>> = hist
        .iter()
        .map(|(s, l)| vec![s.to_string(), format!("{l:.6}")])
        .collect();
    write_csv(std::path::Path::new("results/train_e2e_loss.csv"), &["step", "loss"], &rows)?;
    anyhow::ensure!(
        hist.last().unwrap().1 < hist.first().unwrap().1,
        "loss did not decrease"
    );

    // Float reference at the same budget.
    let float_cfg = RunConfig { alg: "float".into(), ..cfg.clone() };
    let float_outcome = trainer.run(&float_cfg)?;
    println!(
        "\ntest accuracy: A2Q(P=16) {:.4} vs float {:.4} ({:.1}% retained)",
        outcome.perf,
        float_outcome.perf,
        100.0 * outcome.perf / float_outcome.perf
    );

    // Audit: every constrained layer satisfies Eq. 15.
    anyhow::ensure!(outcome.guarantee_ok, "Eq. 15 audit failed");
    println!("\nper-layer max ||w_int||_1 vs cap (2^(P-1)-1)*2^(1s-N):");
    let cap = l1_cap(16, 6, false);
    for (layer, meta) in outcome
        .exported
        .as_ref()
        .unwrap()
        .iter()
        .zip(&trainer.manifest.qlayers)
    {
        let q = layer.to_qtensor();
        println!(
            "  {:<6} max_l1 {:>8}  sparsity {:.2}  {}",
            layer.name,
            q.max_l1(),
            q.sparsity(),
            if format!("{:?}", meta.p_bits).contains('P') {
                format!("cap {cap:.1}")
            } else {
                "(boundary layer, unconstrained)".to_string()
            }
        );
    }
    println!("overall constrained-layer sparsity: {:.3}", outcome.sparsity);

    // Checkpoint round trip.
    let ckpt = Checkpoint::capture(&trainer.manifest, &cfg.alg, steps, &outcome.state)?;
    let path = std::path::Path::new("results/train_e2e.ckpt.json");
    ckpt.save(path)?;
    let restored = Checkpoint::load(path)?.restore(&trainer.manifest)?;
    let perf2 = trainer.evaluate(&restored, &cfg.alg, cfg.bits())?;
    anyhow::ensure!(
        (perf2 - outcome.perf).abs() < 1e-9,
        "restore drift: {perf2} vs {}",
        outcome.perf
    );
    println!("checkpoint round trip: bit-exact ({} leaves, {:?})", trainer.manifest.state.len(), path);
    Ok(())
}
