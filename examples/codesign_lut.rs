//! HW-SW co-design (paper §5.3 in miniature): train one config per
//! accumulator policy and compare FINN-style LUT estimates.
//!
//! Shows the Fig. 6 mechanism end to end: the same (M, N) budget costs very
//! different LUTs depending on how the accumulator is chosen, and A2Q turns
//! the accumulator into a *design input* while guaranteeing correctness.
//!
//! Run: `cargo run --release --example codesign_lut [model] [steps]`

use a2q::config::RunConfig;
use a2q::coordinator::Trainer;
use a2q::finn::estimate::{estimate_network, AccumulatorPolicy, DEFAULT_CYCLES_BUDGET};
use a2q::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "cnn".to_string());
    let steps: u64 = std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(200);
    let (m, n, p_target) = (6u32, 6u32, 14u32);

    let engine = Engine::new("artifacts")?;
    let manifest = engine.manifest(&model)?;
    let geoms = manifest.geoms()?;

    // Train QAT (accumulator-oblivious) and A2Q (accumulator-aware) once each.
    let qat_cfg = RunConfig::new(&model, "qat", m, n, 32, steps);
    let trainer = Trainer::new(&engine, &qat_cfg)?;
    let qat = trainer.run(&qat_cfg)?;
    let a2q_cfg = RunConfig::new(&model, "a2q", m, n, p_target, steps);
    let a2q = trainer.run(&a2q_cfg)?;
    anyhow::ensure!(a2q.guarantee_ok, "Eq. 15 audit failed");

    println!(
        "{model} @ M={m} N={n} (cycles budget {DEFAULT_CYCLES_BUDGET}), A2Q target P={p_target}\n"
    );
    println!(
        "{:<28} {:>10} {:>10} {:>10} {:>8}",
        "co-design setting", "compute", "memory", "total", "perf"
    );
    let mut base_total = None;
    for (name, policy, l1, perf) in [
        ("qat + fixed 32-bit acc", AccumulatorPolicy::Fixed32, &qat.l1_norms, qat.perf),
        ("qat + data-type bound", AccumulatorPolicy::DataTypeBound, &qat.l1_norms, qat.perf),
        ("qat + PTM (weight bound)", AccumulatorPolicy::WeightNorm, &qat.l1_norms, qat.perf),
        (
            "a2q + target P",
            AccumulatorPolicy::A2qTarget(p_target),
            &a2q.l1_norms,
            a2q.perf,
        ),
    ] {
        let est = estimate_network(&geoms, (m, n, p_target), policy, Some(l1), DEFAULT_CYCLES_BUDGET);
        let total = est.total_luts();
        if base_total.is_none() {
            base_total = Some(total);
        }
        println!(
            "{:<28} {:>10.0} {:>10.0} {:>10.0} {:>8.4}   ({:.2}x vs fixed32)",
            name,
            est.total.compute,
            est.total.memory,
            total,
            perf,
            base_total.unwrap() / total
        );
    }

    // Per-layer accumulator widths under A2Q (Fig. 7's mechanism).
    let est = estimate_network(
        &geoms,
        (m, n, p_target),
        AccumulatorPolicy::A2qTarget(p_target),
        Some(&a2q.l1_norms),
        DEFAULT_CYCLES_BUDGET,
    );
    println!("\nper-layer accumulators under A2Q (boundary layers use their weight bound):");
    for l in &est.layers {
        println!(
            "  {:<6} P={:>2}  pe={:<3} simd={:<4} compute {:>8.0}  memory {:>8.0}",
            l.name, l.p_used, l.pe, l.simd, l.luts.compute, l.luts.memory
        );
    }
    Ok(())
}
